//! The Table III/IV/V experiment driver: train a stand-in LLM on the
//! synthetic corpus, apply each A-W quantization configuration (direct
//! cast, PTS, HiGPTQ), evaluate on the benchmark suite, and report
//! accuracy + Acc Drop rows exactly like the paper's tables.

use super::gptq::{gptq_quantize, GptqConfig};
use crate::eval::harness::{evaluate, EvalRow};
use crate::eval::tasks::{self, Task};
use crate::formats::{QuantKind, QuantScheme};
use crate::model::config::ModelConfig;
use crate::model::train::train;
use crate::model::transformer::{Calibration, QuantPolicy, Transformer};
use crate::tensor::Rng;

/// The execution-mode axis of the accuracy matrix, separated from the
/// format axis so the battery (and its JSON keys) can sweep `format ×
/// mode` without hand-listing every combination. [`QuantMode::key`] is the
/// machine spelling; [`QuantType::label`] stays the human table label.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantMode {
    /// Direct-cast RTN (simulated quantization).
    Direct,
    /// RTN behind software per-tensor scaling.
    Pts,
    /// GPTQ weight calibration (HiGPTQ grids — all five formats).
    Gptq,
    /// The real fixed-point path (prepacked integer planes + QGEMM).
    Fixed,
}

impl QuantMode {
    /// Every mode, in the canonical reporting order.
    pub const ALL: [QuantMode; 4] =
        [QuantMode::Direct, QuantMode::Pts, QuantMode::Gptq, QuantMode::Fixed];

    /// Canonical lower-case spelling (bench-JSON key suffix, CLI value).
    pub fn key(self) -> &'static str {
        match self {
            QuantMode::Direct => "direct",
            QuantMode::Pts => "pts",
            QuantMode::Gptq => "gptq",
            QuantMode::Fixed => "fixed",
        }
    }

    /// Cross this mode with one block format.
    pub fn apply(self, kind: QuantKind) -> QuantType {
        match self {
            QuantMode::Direct => QuantType::Direct(kind),
            QuantMode::Pts => QuantType::Pts(kind),
            QuantMode::Gptq => QuantType::HiGptq(kind),
            QuantMode::Fixed => QuantType::Packed(kind),
        }
    }
}

/// An A-W quantization configuration of the paper's tables: an execution
/// mode crossed with one [`QuantKind`]. Any of the five block formats
/// composes with any mode ([`crate::quant::gptq`] freezes per-group
/// metadata grids for every format), so the eval harness can run the full
/// cross-format accuracy matrix the comparison papers use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantType {
    /// Full precision (the baseline every Acc-Drop row subtracts).
    Bf16,
    /// Direct-cast simulated quantization (quant-dequant + f32 GEMMs).
    Direct(QuantKind),
    /// Direct cast with software per-tensor scaling (NVFP4's rescue).
    Pts(QuantKind),
    /// The *real* fixed-point path: weights prepacked into integer operand
    /// planes, activations quantized at each linear, GEMMs on the
    /// [`crate::dotprod::kernel`]-selected QGEMM backend.
    Packed(QuantKind),
    /// HiGPTQ weight calibration, then direct-cast activations.
    HiGptq(QuantKind),
}

impl QuantType {
    /// Table label, derived from the one [`QuantKind`] display impl so
    /// bench JSON, eval tables and `hif4 info` agree on names.
    pub fn label(self) -> String {
        match self {
            QuantType::Bf16 => "BF16".to_string(),
            QuantType::Direct(k) => k.to_string(),
            QuantType::Pts(k) => format!("{k}+PTS"),
            QuantType::Packed(k) => format!("{k} (fixed-point)"),
            QuantType::HiGptq(k) => format!("{k}+HiGPTQ"),
        }
    }

    /// Machine-readable key (`bf16`, `hif4`, `nvfp4+pts`, `hif4+gptq`,
    /// `mx4+fixed`): [`QuantKind::spelling`] `[+ QuantMode::key]`. The one
    /// bench-JSON spelling; [`std::str::FromStr`] round-trips it *and* the
    /// [`QuantType::label`] form, so a renamed mode cannot silently fork
    /// the battery keys from the table labels.
    pub fn key(self) -> String {
        match (self.kind(), self.mode()) {
            (None, _) => "bf16".to_string(),
            (Some(k), Some(QuantMode::Direct)) => k.spelling().to_string(),
            (Some(k), Some(m)) => format!("{}+{}", k.spelling(), m.key()),
            (Some(_), None) => unreachable!("quantized type without a mode"),
        }
    }

    /// The mode axis of this configuration (`None` = the BF16 baseline).
    pub fn mode(self) -> Option<QuantMode> {
        match self {
            QuantType::Bf16 => None,
            QuantType::Direct(_) => Some(QuantMode::Direct),
            QuantType::Pts(_) => Some(QuantMode::Pts),
            QuantType::HiGptq(_) => Some(QuantMode::Gptq),
            QuantType::Packed(_) => Some(QuantMode::Fixed),
        }
    }

    /// The format axis of this configuration (`None` = the BF16 baseline).
    pub fn kind(self) -> Option<QuantKind> {
        match self {
            QuantType::Bf16 => None,
            QuantType::Direct(k)
            | QuantType::Pts(k)
            | QuantType::Packed(k)
            | QuantType::HiGptq(k) => Some(k),
        }
    }

    /// Weight/activation scheme (None = full precision).
    pub fn scheme(self) -> Option<QuantScheme> {
        match self {
            QuantType::Bf16 => None,
            QuantType::Pts(k) => Some(QuantScheme::with_pts(k)),
            QuantType::Direct(k) | QuantType::Packed(k) | QuantType::HiGptq(k) => {
                Some(QuantScheme::direct(k))
            }
        }
    }
}

impl std::str::FromStr for QuantType {
    type Err = String;

    /// The one quant-configuration parser: accepts both the machine key
    /// (`hif4+gptq`, `nvfp4+pts`, `bf16`) and the table label
    /// (`HiF4+HiGPTQ`, `NVFP4+PTS`, `HiF4 (fixed-point)`, `BF16`),
    /// case-insensitively. Format names go through the single
    /// [`QuantKind`] parser, so its error text (listing the valid names)
    /// surfaces here too.
    fn from_str(s: &str) -> Result<QuantType, String> {
        let lower = s.trim().to_ascii_lowercase();
        // The Packed table label spells its mode as a parenthetical.
        let norm = lower.replace(" (fixed-point)", "+fixed");
        if norm == "bf16" {
            return Ok(QuantType::Bf16);
        }
        let (base, suffix) = match norm.split_once('+') {
            Some((b, m)) => (b, Some(m)),
            None => (norm.as_str(), None),
        };
        let kind: QuantKind = base.trim().parse()?;
        match suffix.map(str::trim) {
            None => Ok(QuantType::Direct(kind)),
            Some("pts") => Ok(QuantType::Pts(kind)),
            Some("gptq") | Some("higptq") => Ok(QuantType::HiGptq(kind)),
            Some("fixed") | Some("fixed-point") => Ok(QuantType::Packed(kind)),
            Some(other) => Err(format!(
                "unknown quant mode suffix {other:?}; expected pts, gptq or fixed"
            )),
        }
    }
}

/// Experiment knobs (shrunk by tests, full-size in the benches).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub train_steps: usize,
    pub lr: f32,
    pub batch: usize,
    pub seq: usize,
    pub eval_items: usize,
    pub eval_seeds: Vec<u64>,
    pub calib_rows: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            train_steps: 260,
            lr: 2e-3,
            batch: 8,
            seq: 32,
            eval_items: 60,
            eval_seeds: vec![11, 22, 33],
            calib_rows: 256,
        }
    }
}

/// Train one stand-in model on the synthetic corpus (+ outlier injection
/// afterwards, for the wide-distribution models). Returns the model and
/// its loss curve.
pub fn train_model(
    cfg: &ModelConfig,
    xcfg: &ExperimentConfig,
    seed: u64,
) -> (Transformer, Vec<f32>) {
    assert_eq!(cfg.vocab, tasks::VOCAB, "zoo models must use the corpus vocab");
    let mut model = Transformer::init(cfg.clone(), seed);
    let (batch, seq) = (xcfg.batch, xcfg.seq);
    let losses = train(&mut model, xcfg.train_steps, xcfg.lr, seed ^ 0xC0FFEE, |rng| {
        (0..batch).map(|_| tasks::training_sequence(rng, seq)).collect()
    });
    model.inject_outliers();
    (model, losses)
}

/// Apply one quant type to a trained model, returning the model to
/// evaluate plus the activation policy.
pub fn quantize_model(
    model: &Transformer,
    qt: QuantType,
    xcfg: &ExperimentConfig,
) -> (Transformer, Option<QuantPolicy>) {
    let Some(scheme) = qt.scheme() else {
        return (model.clone(), None);
    };
    let mut qm = model.clone();
    match qt {
        QuantType::Packed(kind) => {
            // Real-quantized execution: weights become packed integer
            // planes held across every forward; activations quantize
            // inside the packed linears, so no fake-quant policy applies
            // on top. Works for every block format — the packed QGEMM is
            // format-generic.
            qm.prepack_quantized_weights(kind);
            return (qm, None);
        }
        QuantType::HiGptq(kind) => {
            // Calibrate on corpus text, then HiGPTQ each quantized linear.
            let mut calib = Calibration::new(xcfg.calib_rows);
            let mut rng = Rng::seed(0x0CA11B);
            for _ in 0..(xcfg.calib_rows / (xcfg.batch * xcfg.seq)).max(1) {
                let batch: Vec<Vec<usize>> =
                    (0..xcfg.batch).map(|_| tasks::training_sequence(&mut rng, xcfg.seq)).collect();
                model.forward(&batch, None, Some(&mut calib), None);
            }
            let gcfg = GptqConfig { format: kind, ..GptqConfig::higptq() };
            qm.visit_linears_mut(&mut |lin| {
                if !lin.kind.quantized_by_paper() {
                    return;
                }
                match calib.inputs.get(&lin.name) {
                    Some(x) if x.rows >= 8 => {
                        lin.w = gptq_quantize(&lin.w, x, &gcfg).weights;
                    }
                    // Unseen linears (e.g. never-routed MoE experts): RTN
                    // through the shared (row-parallel) baseline path.
                    _ => {
                        lin.w = super::gptq::rtn_quantize(&lin.w, &gcfg);
                    }
                }
            });
        }
        _ => qm.quantize_weights(&scheme),
    }
    (qm, Some(QuantPolicy { act: Some(scheme), kv: None }))
}

/// One table block: per-quant-type eval rows (+ drops vs the BF16 row).
#[derive(Debug, Clone)]
pub struct ModelBlock {
    pub model_name: String,
    pub losses: Vec<f32>,
    pub rows: Vec<EvalRow>,
}

impl ModelBlock {
    /// Acc Drop row for `rows[i]` (vs rows[0] = BF16).
    pub fn drops(&self, i: usize) -> Vec<f64> {
        self.rows[i]
            .task_acc
            .iter()
            .zip(&self.rows[0].task_acc)
            .map(|(q, b)| q - b)
            .collect()
    }
}

/// Run the full pipeline for one model over the given quant types.
pub fn run_model(
    cfg: &ModelConfig,
    suite: &[Task],
    quant_types: &[QuantType],
    xcfg: &ExperimentConfig,
    seed: u64,
) -> ModelBlock {
    let (model, losses) = train_model(cfg, xcfg, seed);
    let mut rows = Vec::new();
    for qt in quant_types {
        let (qm, policy) = quantize_model(&model, *qt, xcfg);
        rows.push(evaluate(
            &qm,
            &qt.label(),
            suite,
            xcfg.eval_items,
            &xcfg.eval_seeds,
            policy.as_ref(),
        ));
    }
    ModelBlock { model_name: cfg.name.clone(), losses, rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn quick() -> ExperimentConfig {
        ExperimentConfig {
            train_steps: 60,
            eval_items: 25,
            eval_seeds: vec![1],
            calib_rows: 128,
            ..Default::default()
        }
    }

    #[test]
    fn quant_type_key_and_label_roundtrip() {
        // Every mode × format (plus the baseline) round-trips through BOTH
        // spellings — the bench-JSON key and the human table label — so a
        // renamed mode can't silently fork the battery keys from the
        // tables (`quant/sweep.rs` and `eval/battery.rs` share this
        // parser).
        let mut all = vec![QuantType::Bf16];
        for m in QuantMode::ALL {
            for k in QuantKind::ALL {
                all.push(m.apply(k));
            }
        }
        for qt in all {
            let key = qt.key();
            assert_eq!(key.parse::<QuantType>(), Ok(qt), "key {key:?}");
            let label = qt.label();
            assert_eq!(label.parse::<QuantType>(), Ok(qt), "label {label:?}");
            // Keys are lower-case, '+'-separated, stable spellings.
            assert_eq!(key, key.to_ascii_lowercase());
            // Mode/kind accessors agree with the constructor axes.
            match qt {
                QuantType::Bf16 => assert_eq!((qt.kind(), qt.mode()), (None, None)),
                _ => assert_eq!(qt.mode().unwrap().apply(qt.kind().unwrap()), qt),
            }
        }
        // Labels and keys of distinct configurations never collide.
        let mut keys: Vec<String> = QuantMode::ALL
            .iter()
            .flat_map(|m| QuantKind::ALL.iter().map(|k| m.apply(*k).key()))
            .collect();
        keys.push(QuantType::Bf16.key());
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n, "duplicate battery keys");
        // Bad spellings fail with the shared QuantKind error text.
        assert!("int4".parse::<QuantType>().unwrap_err().contains("hif4"));
        assert!("hif4+awq".parse::<QuantType>().unwrap_err().contains("expected pts"));
    }

    #[test]
    fn pipeline_produces_table_shape() {
        let cfg = zoo::llama2_tiny();
        let block = run_model(
            &cfg,
            &Task::small_suite(),
            &[QuantType::Bf16, QuantType::Direct(QuantKind::HiF4)],
            &quick(),
            1,
        );
        assert_eq!(block.rows.len(), 2);
        assert_eq!(block.rows[0].task_acc.len(), 8);
        assert!(block.losses.last().unwrap() < &block.losses[0], "training works");
        let drops = block.drops(1);
        assert_eq!(drops.len(), 8);
        // HiF4 direct cast stays within a plausible drop band.
        assert!(block.rows[1].mean >= block.rows[0].mean - 25.0);
    }

    #[test]
    fn packed_fixed_point_path_stays_in_simulated_accuracy_band() {
        // The real-quantized kernel path uses the same quantized operands
        // as the simulated path (only GEMM accumulation differs), so the
        // two HiF4 rows must land close together on the eval suite.
        let cfg = zoo::llama2_tiny();
        let xcfg = ExperimentConfig {
            train_steps: 40,
            eval_items: 20,
            eval_seeds: vec![1],
            calib_rows: 64,
            ..Default::default()
        };
        let block = run_model(
            &cfg,
            &[Task::AgreeEasy, Task::Physical],
            &[QuantType::Direct(QuantKind::HiF4), QuantType::Packed(QuantKind::HiF4)],
            &xcfg,
            4,
        );
        let sim = block.rows[0].mean;
        let real = block.rows[1].mean;
        assert!(
            (sim - real).abs() < 20.0,
            "fixed-point path drifted from simulated: sim={sim:.1} real={real:.1}"
        );
    }

    #[test]
    fn outlier_model_crashes_nvfp4_but_not_hif4() {
        // The §IV.B "Mistral crash": the wide-distribution model must hurt
        // NVFP4 direct-cast far more than HiF4 direct-cast.
        let cfg = zoo::mistral_tiny();
        let block = run_model(
            &cfg,
            &[Task::AgreeEasy, Task::Physical],
            &[
                QuantType::Bf16,
                QuantType::Direct(QuantKind::Nvfp4),
                QuantType::Direct(QuantKind::HiF4),
            ],
            &quick(),
            2,
        );
        let bf16 = block.rows[0].mean;
        let nvfp4 = block.rows[1].mean;
        let hif4 = block.rows[2].mean;
        assert!(
            bf16 - nvfp4 > 2.0 * (bf16 - hif4).max(1.0),
            "NVFP4 should crash on the outlier model: bf16={bf16:.1} nvfp4={nvfp4:.1} hif4={hif4:.1}"
        );
    }
}
