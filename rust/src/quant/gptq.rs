//! GPTQ and HiGPTQ (§IV.A).
//!
//! Vanilla GPTQ [19] quantizes a linear layer's weight matrix column by
//! column, propagating each column's quantization error into the remaining
//! columns through the inverse Hessian of the layer inputs
//! (`H = X Xᵀ + λI`).
//!
//! **HiGPTQ** is the paper's HiF4-tailored adaptation: the K axis (input
//! features) is blocked into HiF4's 64-element groups; at each group
//! boundary the three-level scaling metadata is *frozen* from the current
//! (error-compensated) weights, and the in-group columns then quantize onto
//! the per-position grid that metadata implies — so error feedback stays
//! consistent with the hierarchical scales. The same machinery with NVFP4's
//! 16-element grid gives a GPTQ-for-NVFP4 baseline (used by the ablation
//! bench; the paper itself pairs GPTQ only with HiF4).

//! ## Parallel execution
//!
//! GPTQ's error feedback propagates along the K axis *within* a weight row
//! and never across rows, so the whole layer quantization is row-parallel:
//! [`gptq_quantize_with_hessian_threads`] fans W's rows out over
//! contiguous bands (sharing the one Cholesky factor), and
//! [`hessian_threads`] does the same for H's rows. Both keep each row's
//! floating-point accumulation order fixed, so any thread count yields
//! bit-identical weights, Hessians and proxy losses. The PTQ pipeline
//! (`quant::experiment`, `server` startup weight quantization) calls the
//! default entry points, which use the process-wide thread knob.

use crate::formats::e6m2::exp2i;
use crate::formats::rounding::{round_int, RoundMode};
use crate::formats::{bfp, e2m1, hif4, mx4, mxfp4, nvfp4, s1p2, QuantKind};
use crate::tensor::Matrix;
use crate::util::threadpool::{self, parallel_row_bands, parallel_row_bands2};

/// Dampening factor: λ = DAMP × mean(diag(H)).
pub const DAMP: f64 = 0.01;

/// Which per-position grid a frozen-metadata group exposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum GridKind {
    /// Uniform ±1.75 sign-magnitude grid of step 0.25 × scale (HiF4, BFP).
    S1P2,
    /// Non-uniform E2M1 magnitude grid × scale (NVFP4, MXFP4).
    E2M1,
    /// Uniform ±1.5 sign-magnitude grid of step 0.5 × scale (MX4's 3-bit
    /// element; the per-position step already folds the micro-exponent in).
    S1P1,
}

/// Frozen-metadata quantization grid for one (row, K-group) pair.
#[derive(Debug, Clone)]
struct GroupGrid {
    kind: GridKind,
    /// Effective scale per element position (scale × 2^(l2+l3) for HiF4;
    /// the group scale for NVFP4). Zero scale ⇒ everything quantizes to 0.
    steps: Vec<f32>,
}

impl GroupGrid {
    /// Freeze HiF4 metadata from the current weights of one group.
    fn hif4(w: &[f32], mode: RoundMode) -> GroupGrid {
        debug_assert_eq!(w.len(), hif4::GROUP);
        let (unit, _) = hif4::quantize_trace(w, mode);
        let s = unit.scale.to_f32();
        let steps =
            (0..hif4::GROUP).map(|i| s * exp2i((unit.l2(i) + unit.l3(i)) as i32)).collect();
        GroupGrid { kind: GridKind::S1P2, steps }
    }

    /// Freeze NVFP4 metadata (E4M3 scale) from the current weights.
    fn nvfp4(w: &[f32], mode: RoundMode) -> GroupGrid {
        debug_assert_eq!(w.len(), nvfp4::GROUP);
        let g = nvfp4::quantize(w, mode);
        let s = g.scale.to_f32();
        GroupGrid { kind: GridKind::E2M1, steps: vec![s; nvfp4::GROUP] }
    }

    /// Freeze MXFP4 metadata (E8M0 scale) from the current weights.
    fn mxfp4(w: &[f32], mode: RoundMode) -> GroupGrid {
        debug_assert_eq!(w.len(), mxfp4::GROUP);
        let g = mxfp4::quantize(w, mode);
        let s = g.scale.to_f32();
        GroupGrid { kind: GridKind::E2M1, steps: vec![s; mxfp4::GROUP] }
    }

    /// Freeze MX4 metadata (E8M0 scale + per-sub-group micro-exponents)
    /// from the current weights; the micro bit folds into each position's
    /// effective step, so in-group error feedback quantizes onto exactly
    /// the grid the frozen metadata implies.
    fn mx4(w: &[f32], mode: RoundMode) -> GroupGrid {
        debug_assert_eq!(w.len(), mx4::GROUP);
        let g = mx4::quantize(w, mode);
        let s = g.scale.to_f32();
        let steps =
            (0..mx4::GROUP).map(|i| s * if g.micro_down(i) == 1 { 0.5 } else { 1.0 }).collect();
        GroupGrid { kind: GridKind::S1P1, steps }
    }

    /// Freeze vanilla-BFP metadata (E8M0 shared exponent) from the current
    /// weights.
    fn bfp(w: &[f32], mode: RoundMode) -> GroupGrid {
        debug_assert_eq!(w.len(), bfp::GROUP);
        let g = bfp::quantize(w, mode);
        let s = g.scale.to_f32();
        GroupGrid { kind: GridKind::S1P2, steps: vec![s; bfp::GROUP] }
    }

    /// Quantize one value at in-group position `i` onto the frozen grid.
    #[inline]
    fn quantize(&self, i: usize, x: f32, mode: RoundMode) -> f32 {
        let s = self.steps[i];
        if s == 0.0 || !s.is_finite() {
            return 0.0;
        }
        match self.kind {
            GridKind::S1P2 => s * s1p2::S1P2::from_f32(x / s, mode).to_f32(),
            GridKind::E2M1 => s * e2m1::E2M1::from_f32(x / s, mode).to_f32(),
            GridKind::S1P1 => {
                // Mirror `mx4::quantize`'s element rule: round halves, clip
                // the magnitude at 3 (|value| ≤ 1.5 × step).
                let q = round_int(x / (s * mx4::ELEM_STEP), mode).clamp(-3.0, 3.0);
                s * mx4::ELEM_STEP * q
            }
        }
    }
}

/// GPTQ configuration.
#[derive(Debug, Clone, Copy)]
pub struct GptqConfig {
    pub format: QuantKind,
    pub mode: RoundMode,
    /// Per-tensor scaling before quantization (NVFP4+PTS pipelines).
    pub pts: bool,
}

impl GptqConfig {
    /// The paper's HiGPTQ: GPTQ adapted to HiF4's hierarchical grid.
    pub fn higptq() -> GptqConfig {
        GptqConfig { format: QuantKind::HiF4, mode: RoundMode::NearestEven, pts: false }
    }

    pub fn group(&self) -> usize {
        self.format.group()
    }

    fn make_grid(&self, w: &[f32]) -> GroupGrid {
        match self.format {
            QuantKind::HiF4 => GroupGrid::hif4(w, self.mode),
            QuantKind::Nvfp4 => GroupGrid::nvfp4(w, self.mode),
            QuantKind::Mxfp4 => GroupGrid::mxfp4(w, self.mode),
            QuantKind::Mx4 => GroupGrid::mx4(w, self.mode),
            QuantKind::Bfp => GroupGrid::bfp(w, self.mode),
        }
    }
}

/// Outcome of quantizing one layer.
#[derive(Debug, Clone)]
pub struct GptqResult {
    /// Fake-quantized weights (same shape as the input W).
    pub weights: Matrix,
    /// Σ over rows of (w−q)ᵀ H (w−q): the proxy loss GPTQ minimizes.
    pub proxy_loss: f64,
}

/// Accumulate the GPTQ Hessian `H = X Xᵀ` from calibration inputs
/// (X: samples × in_features, row-major), in f64. Parallel over H rows
/// with the process-default thread count.
pub fn hessian(x: &Matrix) -> Vec<f64> {
    hessian_threads(x, threadpool::threads_for(x.rows * x.cols * x.cols))
}

/// [`hessian`] with an explicit thread count. Each H row sums its samples
/// in ascending order on one thread, so the result is bit-identical for
/// every count.
pub fn hessian_threads(x: &Matrix, threads: usize) -> Vec<f64> {
    let n = x.cols;
    let mut h = vec![0f64; n * n];
    parallel_row_bands(&mut h, n, threads, |first_row, band| {
        for (ii, hrow) in band.chunks_mut(n).enumerate() {
            let i = first_row + ii;
            for s in 0..x.rows {
                let row = x.row(s);
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                for (hj, xj) in hrow.iter_mut().zip(row) {
                    *hj += xi * *xj as f64;
                }
            }
        }
    });
    h
}

/// Quantize a linear layer `W (out×in)` against calibration inputs
/// `X (samples×in)` with GPTQ error compensation.
pub fn gptq_quantize(w: &Matrix, x: &Matrix, cfg: &GptqConfig) -> GptqResult {
    assert_eq!(w.cols, x.cols, "W in_features must match X features");
    let h = hessian(x);
    gptq_quantize_with_hessian(w, &h, cfg)
}

/// GPTQ with a precomputed Hessian (callers that calibrate once and
/// quantize several candidate formats reuse it). Row-parallel with the
/// process-default thread count.
pub fn gptq_quantize_with_hessian(w: &Matrix, h: &[f64], cfg: &GptqConfig) -> GptqResult {
    let threads = threadpool::threads_for(w.rows * w.cols * w.cols);
    gptq_quantize_with_hessian_threads(w, h, cfg, threads)
}

/// [`gptq_quantize_with_hessian`] with an explicit thread count.
///
/// GPTQ's error feedback stays within a weight row, so rows quantize
/// independently against the shared Cholesky factor; per-row losses are
/// reduced in ascending row order afterwards. Bit-identical for every
/// thread count.
pub fn gptq_quantize_with_hessian_threads(
    w: &Matrix,
    h: &[f64],
    cfg: &GptqConfig,
    threads: usize,
) -> GptqResult {
    let n = w.cols;
    assert_eq!(h.len(), n * n);

    // Dampen: λ = DAMP × mean diag; dead columns (zero diag) get λ too.
    let mut hd = h.to_vec();
    let mean_diag = (0..n).map(|i| hd[i * n + i]).sum::<f64>() / n as f64;
    let lambda = (DAMP * mean_diag).max(1e-8);
    for i in 0..n {
        hd[i * n + i] += lambda;
    }

    // Hinv = H⁻¹ via Cholesky, then the upper Cholesky factor of Hinv —
    // GPTQ's standard formulation.
    let hinv = invert_spd(&hd, n);
    let u = cholesky_upper(&hinv, n);

    // PTS wraps the whole tensor.
    let t = if cfg.pts { nvfp4::pts_scale(&w.data) } else { 1.0 };

    let mut wq = Matrix::zeros(w.rows, w.cols);
    let mut row_losses = vec![0f64; w.rows];
    if w.rows > 0 && n > 0 {
        parallel_row_bands2(&mut wq.data, n, &mut row_losses, 1, threads, |first_row, qb, lb| {
            for (i, loss) in lb.iter_mut().enumerate() {
                *loss = gptq_quantize_row(
                    w.row(first_row + i),
                    &u,
                    cfg,
                    t,
                    &mut qb[i * n..(i + 1) * n],
                );
            }
        });
    }

    if t != 1.0 {
        wq.scale_inplace(1.0 / t);
    }
    GptqResult { weights: wq, proxy_loss: row_losses.iter().sum() }
}

/// Quantize one weight row against the upper Cholesky factor `u`,
/// freezing per-group metadata from the error-compensated weights at each
/// group boundary — the Hi in HiGPTQ. Returns the row's proxy loss.
fn gptq_quantize_row(wrow: &[f32], u: &[f64], cfg: &GptqConfig, t: f32, qrow: &mut [f32]) -> f64 {
    let n = wrow.len();
    let g = cfg.group();
    let mut cur = wrow.to_vec();
    if t != 1.0 {
        for x in cur.iter_mut() {
            *x *= t;
        }
    }
    let mut gbuf = vec![0f32; g];
    let mut loss = 0f64;
    for j0 in (0..n).step_by(g) {
        let end = (j0 + g).min(n);
        gbuf[..end - j0].copy_from_slice(&cur[j0..end]);
        gbuf[end - j0..].fill(0.0);
        let grid = cfg.make_grid(&gbuf);
        for j in j0..end {
            let ujj = u[j * n + j];
            let wv = cur[j];
            let q = grid.quantize(j - j0, wv, cfg.mode);
            qrow[j] = q;
            let err = (wv - q) as f64 / ujj;
            loss += err * err;
            // Propagate into the remaining columns of this row.
            if err != 0.0 {
                let urow = &u[j * n..(j + 1) * n];
                for (ck, uk) in cur[j + 1..].iter_mut().zip(&urow[j + 1..]) {
                    *ck -= (err * uk) as f32;
                }
            }
        }
    }
    loss
}

/// Round-to-nearest baseline (direct cast of each row) — what the tables'
/// non-GPTQ rows use; shares the grid code path for comparability.
/// Row-parallel; rows quantize independently so the result is identical
/// for any thread count.
pub fn rtn_quantize(w: &Matrix, cfg: &GptqConfig) -> Matrix {
    let scheme = crate::formats::QuantScheme { format: cfg.format, pts: cfg.pts, mode: cfg.mode };
    Matrix::from_vec(w.rows, w.cols, scheme.quant_dequant_rows(&w.data, w.cols))
}

/// Invert a symmetric positive-definite matrix via Cholesky (f64, n ≤ ~2k).
fn invert_spd(a: &[f64], n: usize) -> Vec<f64> {
    let l = cholesky_lower(a, n);
    // Solve L Y = I, then Lᵀ X = Y.
    let mut inv = vec![0f64; n * n];
    for col in 0..n {
        // Forward substitution for y.
        let mut y = vec![0f64; n];
        for i in 0..n {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l[i * n + k] * y[k];
            }
            y[i] = s / l[i * n + i];
        }
        // Back substitution for x.
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= l[k * n + i] * inv[k * n + col];
            }
            inv[i * n + col] = s / l[i * n + i];
        }
    }
    inv
}

/// Lower Cholesky factor of an SPD matrix.
fn cholesky_lower(a: &[f64], n: usize) -> Vec<f64> {
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                assert!(s > 0.0, "matrix not positive definite at {i} (s={s})");
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    l
}

/// Upper Cholesky factor U with A = Uᵀ U — i.e. U = Lᵀ for A = L Lᵀ
/// (torch.linalg.cholesky(·, upper=True) semantics, which GPTQ uses).
fn cholesky_upper(a: &[f64], n: usize) -> Vec<f64> {
    let l = cholesky_lower(a, n);
    let mut u = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = l[i * n + j];
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn spd(n: usize, rng: &mut Rng) -> Vec<f64> {
        // A = B Bᵀ + n·I.
        let b = Matrix::randn(n, n, 1.0, rng);
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0f64;
                for k in 0..n {
                    s += (b.at(i, k) as f64) * (b.at(j, k) as f64);
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::seed(401);
        let n = 8;
        let a = spd(n, &mut rng);
        let l = cholesky_lower(&a, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0f64;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Rng::seed(402);
        let n = 10;
        let a = spd(n, &mut rng);
        let inv = invert_spd(&a, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0f64;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn upper_cholesky_reconstructs() {
        let mut rng = Rng::seed(403);
        let n = 7;
        let a = spd(n, &mut rng);
        let u = cholesky_upper(&a, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0f64;
                for k in 0..n {
                    s += u[k * n + i] * u[k * n + j];
                }
                assert!((s - a[i * n + j]).abs() < 1e-9, "UᵀU != A at ({i},{j})");
            }
        }
        // Upper-triangular check.
        for i in 1..n {
            for j in 0..i {
                assert_eq!(u[i * n + j], 0.0);
            }
        }
    }

    #[test]
    fn higptq_beats_rtn_on_correlated_inputs() {
        // The whole point of GPTQ: with correlated calibration inputs the
        // compensated quantization has lower output MSE than RTN.
        let mut rng = Rng::seed(404);
        let (out_f, in_f, samples) = (16, 128, 64);
        let w = Matrix::randn(out_f, in_f, 0.05, &mut rng);
        // Correlated inputs: x = base + noise.
        let mut x = Matrix::zeros(samples, in_f);
        for s in 0..samples {
            let base = rng.normal() as f32;
            for j in 0..in_f {
                x.data[s * in_f + j] = base * (0.5 + (j % 7) as f32 * 0.1)
                    + rng.normal() as f32 * 0.3;
            }
        }
        let cfg = GptqConfig::higptq();
        let q_gptq = gptq_quantize(&w, &x, &cfg).weights;
        let q_rtn = rtn_quantize(&w, &cfg);
        // Output error on the calibration set.
        let y = crate::tensor::gemm::matmul_bt(&x, &w);
        let y_gptq = crate::tensor::gemm::matmul_bt(&x, &q_gptq);
        let y_rtn = crate::tensor::gemm::matmul_bt(&x, &q_rtn);
        let e_gptq = y.mse(&y_gptq);
        let e_rtn = y.mse(&y_rtn);
        assert!(
            e_gptq < e_rtn,
            "HiGPTQ output MSE {e_gptq:.3e} should beat RTN {e_rtn:.3e}"
        );
    }

    #[test]
    fn gptq_outputs_live_on_hif4_grids() {
        // Every quantized group must be exactly representable: re-quantizing
        // with RTN on the same data must be a fixed point w.r.t. the grid
        // (|q - rtn(q)| can only differ where metadata differs; check the
        // weaker but meaningful invariant that values lie on *some* S1P2
        // grid: q / step ∈ {-7..7} for the frozen step).
        let mut rng = Rng::seed(405);
        let w = Matrix::randn(4, 64, 0.1, &mut rng);
        let x = Matrix::randn(32, 64, 1.0, &mut rng);
        let cfg = GptqConfig::higptq();
        let q = gptq_quantize(&w, &x, &cfg).weights;
        for r in 0..q.rows {
            let row = q.row(r);
            let nonzero: Vec<f32> = row.iter().copied().filter(|v| *v != 0.0).collect();
            assert!(!nonzero.is_empty());
            // All values must be dyadic rationals with small numerators:
            // v = m × 2^e with |m| ≤ 7×3 (s1p2 × e6m2 mantissa 1..1.75).
            for v in nonzero {
                let b = v.abs().to_bits();
                let mantissa = (b & 0x7F_FFFF) | 0x80_0000;
                let tz = mantissa.trailing_zeros();
                let sig = mantissa >> tz;
                assert!(sig <= 105, "{v} not on a HiF4 grid (sig={sig})");
            }
        }
    }

    #[test]
    fn frozen_grids_match_rtn_per_group() {
        // With no error feedback, quantizing a fresh group through its
        // frozen grid must reproduce the format's own quant-dequant bit
        // for bit — the grids exist to *freeze* that metadata, not to
        // approximate it. (E8M0 scales are powers of two, so the grid's
        // division and the format's reciprocal multiply agree exactly.
        // HiF4/NVFP4 use non-power-of-two scales and are covered by the
        // dyadic-grid and MSE tests instead.)
        use crate::formats::QuantScheme;
        let mut rng = Rng::seed(407);
        for f in [QuantKind::Mxfp4, QuantKind::Mx4, QuantKind::Bfp] {
            let cfg = GptqConfig { format: f, mode: RoundMode::NearestEven, pts: false };
            let g = f.group();
            for _ in 0..25 {
                let v: Vec<f32> = (0..g).map(|_| rng.normal() as f32 * 0.3).collect();
                let grid = cfg.make_grid(&v);
                let want = QuantScheme::direct(f).quant_dequant_vec(&v);
                for i in 0..g {
                    let got = grid.quantize(i, v[i], cfg.mode);
                    assert_eq!(
                        got.to_bits(),
                        want[i].to_bits(),
                        "{f}: pos {i}, x={} grid={got} rtn={}",
                        v[i],
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn gptq_covers_all_formats() {
        // Every block format must run through GPTQ with finite outputs and
        // stay competitive with RTN on its own calibration set.
        let mut rng = Rng::seed(408);
        let w = Matrix::randn(6, 64, 0.05, &mut rng);
        let x = Matrix::randn(32, 64, 1.0, &mut rng);
        let y = crate::tensor::gemm::matmul_bt(&x, &w);
        for f in QuantKind::ALL {
            let cfg = GptqConfig { format: f, mode: RoundMode::NearestEven, pts: false };
            let r = gptq_quantize(&w, &x, &cfg);
            assert!(r.proxy_loss.is_finite(), "{f}: proxy loss must be finite");
            assert!(r.weights.data.iter().all(|v| v.is_finite()), "{f}: weights must be finite");
            let e_g = y.mse(&crate::tensor::gemm::matmul_bt(&x, &r.weights));
            let e_r = y.mse(&crate::tensor::gemm::matmul_bt(&x, &rtn_quantize(&w, &cfg)));
            assert!(
                e_g <= e_r * 1.05 + 1e-12,
                "{f}: GPTQ output MSE {e_g:.3e} should not trail RTN {e_r:.3e}"
            );
        }
    }

    #[test]
    fn nvfp4_gptq_runs() {
        let mut rng = Rng::seed(406);
        let w = Matrix::randn(8, 48, 0.05, &mut rng);
        let x = Matrix::randn(32, 48, 1.0, &mut rng);
        let cfg =
            GptqConfig { format: QuantKind::Nvfp4, mode: RoundMode::NearestEven, pts: false };
        let r = gptq_quantize(&w, &x, &cfg);
        assert!(r.proxy_loss.is_finite());
        assert_eq!(r.weights.rows, 8);
    }
}
