//! Quantization pipeline: error sweeps (Fig 3), per-tensor scaling, GPTQ and
//! the HiF4-tailored HiGPTQ (§IV.A).

pub mod experiment;
pub mod gptq;
pub mod sweep;
