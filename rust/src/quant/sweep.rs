//! The Fig-3 quantization-error experiment (§III.A).
//!
//! 18 Gaussian 1024×1024 matrices, σ = 0.01 × 2^x for x ∈ [0, 17]; each is
//! quantized to HiF4, MXFP4, NVFP4 (direct cast) and NVFP4+PTS; MSE against
//! the original is reported normalized to HiF4's.

use crate::formats::{mse, QuantKind, QuantScheme};
use crate::tensor::{Matrix, Rng};

/// Matrix side length of the paper's experiment.
pub const PAPER_DIM: usize = 1024;
/// Number of σ points: x ∈ [0, 17].
pub const PAPER_POINTS: usize = 18;

/// One point of the sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// The exponent x (σ = 0.01 × 2^x).
    pub x: u32,
    pub sigma: f64,
    /// Raw MSE per scheme, in the order of [`schemes`].
    pub mse: Vec<f64>,
    /// MSE normalized to HiF4's.
    pub normalized: Vec<f64>,
}

/// The schemes Fig 3 plots, in plot order.
pub fn schemes() -> Vec<QuantScheme> {
    vec![
        QuantScheme::direct(QuantKind::HiF4),
        QuantScheme::direct(QuantKind::Nvfp4),
        QuantScheme::with_pts(QuantKind::Nvfp4),
        QuantScheme::direct(QuantKind::Mxfp4),
    ]
}

/// Column labels for [`schemes`], derived from [`QuantScheme::label`] — the
/// one label source the CLI table, the Fig-3 bench header and the accuracy
/// battery all share (a renamed scheme renames every consumer at once
/// instead of forking).
pub fn scheme_labels() -> Vec<String> {
    schemes().iter().map(QuantScheme::label).collect()
}

/// Run the sweep at a configurable matrix size (the paper's 1024×1024 by
/// default; tests shrink it).
pub fn run(dim: usize, points: usize, seed: u64) -> Vec<SweepPoint> {
    let schemes = schemes();
    let mut out = Vec::with_capacity(points);
    let mut rng = Rng::seed(seed);
    for x in 0..points as u32 {
        let sigma = 0.01 * 2f64.powi(x as i32);
        let m = Matrix::randn(dim, dim, sigma as f32, &mut rng);
        let mses: Vec<f64> = schemes
            .iter()
            .map(|s| {
                let q = s.quant_dequant_vec(&m.data);
                mse(&m.data, &q)
            })
            .collect();
        let base = mses[0];
        let normalized = mses.iter().map(|e| e / base).collect();
        out.push(SweepPoint { x, sigma, mse: mses, normalized });
    }
    out
}

/// Aggregate ratio over the sweep, excluding points where NVFP4 direct-cast
/// blows up (the paper excludes "NVFP4's fluctuation" when quoting
/// HiF4 : NVFP4 : MXFP4 = 1 : 1.32 : 1.89).
pub fn stable_ratios(points: &[SweepPoint]) -> Vec<f64> {
    let n = schemes().len();
    let mut acc = vec![0f64; n];
    let mut count = 0usize;
    for p in points {
        // NVFP4 is "stable" where direct-cast tracks PTS closely.
        let stable = p.normalized[1] <= p.normalized[2] * 1.5;
        if !stable {
            continue;
        }
        for (a, r) in acc.iter_mut().zip(&p.normalized) {
            *a += r;
        }
        count += 1;
    }
    acc.iter().map(|a| a / count.max(1) as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_labels_roundtrip_through_the_shared_parser() {
        // The Fig-3 header labels and the battery's quant axis must agree:
        // every label parses back (via the shared QuantType parser) to a
        // QuantType whose scheme is exactly the one that produced it.
        use crate::quant::experiment::QuantType;
        let labels = scheme_labels();
        assert_eq!(labels, ["HiF4", "NVFP4", "NVFP4+PTS", "MXFP4"]);
        for (label, scheme) in labels.iter().zip(schemes()) {
            let qt: QuantType = label.parse().unwrap();
            assert_eq!(qt.scheme(), Some(scheme), "{label}");
            assert_eq!(qt.label(), *label, "label must re-derive itself");
        }
    }

    #[test]
    fn fig3_shape_small() {
        // 128×128 is enough to see the Fig-3 shape clearly.
        let pts = run(128, PAPER_POINTS, 42);
        assert_eq!(pts.len(), PAPER_POINTS);
        for p in &pts {
            assert_eq!(p.normalized[0], 1.0, "normalized to HiF4");
            assert!(p.mse.iter().all(|e| e.is_finite() && *e > 0.0));
        }
    }

    #[test]
    fn fig3_ratio_ordering() {
        let pts = run(128, PAPER_POINTS, 43);
        let r = stable_ratios(&pts);
        // Paper: 1 : 1.32 : 1.89 (NVFP4 direct ≈ NVFP4+PTS when stable).
        assert!(r[1] > 1.1 && r[1] < 1.7, "NVFP4/HiF4 ratio {:.3}", r[1]);
        assert!(r[3] > 1.5 && r[3] < 2.6, "MXFP4/HiF4 ratio {:.3}", r[3]);
        assert!(r[3] > r[1], "MXFP4 worse than NVFP4");
    }

    #[test]
    fn nvfp4_blows_up_at_range_edges() {
        // At x = 17 (σ = 0.01×2^17 ≈ 1311) group peaks exceed 2688 → E4M3
        // scale saturates → direct-cast error must blow up vs PTS.
        let pts = run(128, PAPER_POINTS, 44);
        let last = &pts[PAPER_POINTS - 1];
        assert!(
            last.normalized[1] > 1.5 * last.normalized[2],
            "direct {} should blow up vs PTS {}",
            last.normalized[1],
            last.normalized[2]
        );
        // And the direct/PTS gap must widen toward the range edge.
        let gap = |p: &SweepPoint| p.normalized[1] / p.normalized[2];
        assert!(gap(&pts[17]) > gap(&pts[12]), "blow-up grows toward the edge");
        // While HiF4 stays flat: its normalized error is 1 by construction,
        // but also its *raw* error must scale ∝ σ² (no range failure).
        let mid = &pts[8];
        let scaling = last.mse[0] / mid.mse[0];
        let expect = (last.sigma / mid.sigma).powi(2);
        assert!(
            (scaling / expect).log2().abs() < 1.0,
            "HiF4 MSE should scale with σ²: got {scaling:.3e}, expect {expect:.3e}"
        );
    }
}
