//! Gate-level inventory of the 64-length PE datapaths (Fig 4) for the
//! shared base and the per-format increments.
//!
//! Unit convention: 1 gate-unit ≈ one full-adder / one partial-product cell.
//! Mux/shift stages cost [`MUX_FACTOR`] per bit-stage (a 2:1 mux is ~1/3 of
//! a full adder in standard-cell gate counts).

use super::{add_area, mul_area, shift_area};
use crate::dotprod::{hif4_flow, nvfp4_flow};

/// Relative cost of a 1-bit 2:1 mux vs a full adder cell.
pub const MUX_FACTOR: f64 = 0.3;

/// One datapath block with a name (for the report), an area and an activity
/// factor (fraction of cycles the block toggles; 1.0 for every block of a
/// fully-pipelined PE).
#[derive(Debug, Clone)]
pub struct Block {
    pub name: &'static str,
    pub area: f64,
    pub activity: f64,
    pub count: usize,
}

impl Block {
    fn new(name: &'static str, area: f64, count: usize) -> Block {
        Block { name, area, activity: 1.0, count }
    }

    pub fn total_area(&self) -> f64 {
        self.area * self.count as f64
    }

    pub fn total_power(&self) -> f64 {
        self.total_area() * self.activity
    }
}

/// A list of blocks forming (part of) a PE.
#[derive(Debug, Clone)]
pub struct AreaReport {
    pub label: &'static str,
    pub blocks: Vec<Block>,
}

/// Alias: the same structure also carries power (area × activity).
pub type PowerReport = AreaReport;

impl AreaReport {
    pub fn total_area(&self) -> f64 {
        self.blocks.iter().map(Block::total_area).sum()
    }

    pub fn total_power(&self) -> f64 {
        self.blocks.iter().map(Block::total_power).sum()
    }
}

/// The logic shared by every precision mode of the PE (already present for
/// INT8/FP8 per §III.B: "4-bit BFP formats are integrated into existing
/// dot-product units"): 64 small element multipliers, the integer reduction
/// tree, operand registers and the FP32 output accumulator.
///
/// 5×5-bit multipliers serve both S2P2×S2P2 (HiF4) and S3P1×S3P1 (NVFP4)
/// element products; the adder tree is sized for the deepest (HiF4, 17-bit
/// S12P4) reduction.
pub fn shared_base() -> AreaReport {
    let h = hif4_flow::stats();
    AreaReport {
        label: "shared base (64 element muls + tree + regs + fp32 acc)",
        blocks: vec![
            Block::new("5x5 element multiplier", mul_area(5, 5), h.small_int_muls),
            // 63 adders at a representative mean width of ~13 bits
            // (9-bit products widening to 17 at the root).
            Block::new("integer tree adder", add_area(13), 63),
            // 2×64×8-bit operand registers (flop ≈ 1 gate-unit per bit).
            Block::new("operand registers", 8.0, 128),
            // FP32 output accumulator: align + add + normalize ≈ 3 adders.
            Block::new("fp32 output accumulator", 3.0 * add_area(32), 1),
        ],
    }
}

/// HiF4's incremental logic over the shared base (Fig 4 left):
/// element conversion S1P2→S2P2 (level-3 absorb, a 1-stage mux-shift),
/// level-2 span shifters, ONE small FP scale multiplier (E6M2×E6M2:
/// 3×3-bit significands + 7-bit exponent add), ONE large integer
/// multiplier (scale-product significand 6b × S12P4 17b).
pub fn hif4_incremental() -> AreaReport {
    let s = hif4_flow::stats();
    AreaReport {
        label: "HiF4 incremental",
        blocks: vec![
            // S1P2 << E1_16 into the 5-bit multiplier port: 1 mux stage / 5b.
            Block::new("element convert S1P2->S2P2", shift_area(5, 1) * MUX_FACTOR, 64),
            // Level-2 span shift: 8 shifters, 13-bit span sums, 2 stages.
            Block::new("L2 span shifter", shift_area(13, 2) * MUX_FACTOR, 8),
            Block::new(
                "E6M2xE6M2 scale multiplier",
                mul_area(3, 3) + add_area(7),
                s.small_fp_muls,
            ),
            Block::new(
                "large int multiplier (6b x 17b)",
                mul_area(6, s.final_int_bits),
                s.large_int_muls,
            ),
        ],
    }
}

/// NVFP4's incremental logic (Fig 4 right): element conversion E2M1→S3P1
/// (exponent decode + mux-shift, same order as HiF4's convert), FOUR small
/// FP scale multipliers (E4M3×E4M3: 4×4-bit significands + 5-bit exponent
/// add), FOUR large integer multipliers (scale significand 8b × S10P2 13b),
/// and the final floating-point accumulation (3 FP adders, 25-bit datapath:
/// aligner + mantissa add + normalizer ≈ 3× a plain adder).
pub fn nvfp4_incremental() -> AreaReport {
    let s = nvfp4_flow::stats();
    AreaReport {
        label: "NVFP4 incremental",
        blocks: vec![
            Block::new("element convert E2M1->S3P1", shift_area(5, 1) * MUX_FACTOR, 64),
            Block::new(
                "E4M3xE4M3 scale multiplier",
                mul_area(4, 4) + add_area(5),
                s.small_fp_muls,
            ),
            Block::new(
                "large int multiplier (8b x 13b)",
                mul_area(8, s.final_int_bits),
                s.large_int_muls,
            ),
            Block::new("FP accumulator adder (25b, align+add+norm)", 3.0 * add_area(25), s.fp_adds),
        ],
    }
}

/// Full Table-style report rows: (label, area, power) triples for the bench.
pub fn report_rows() -> Vec<(String, f64, f64)> {
    let base = shared_base();
    let h = hif4_incremental();
    let n = nvfp4_incremental();
    vec![
        (base.label.to_string(), base.total_area(), base.total_power()),
        (h.label.to_string(), h.total_area(), h.total_power()),
        (n.label.to_string(), n.total_area(), n.total_power()),
        (
            "HiF4 whole PE".to_string(),
            base.total_area() + h.total_area(),
            base.total_power() + h.total_power(),
        ),
        (
            "NVFP4 whole PE".to_string(),
            base.total_area() + n.total_area(),
            base.total_power() + n.total_power(),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increments_are_small_vs_base() {
        // The shared element multipliers dominate the PE — sanity of the
        // "integrated into existing dot-product units" premise.
        let base = shared_base().total_area();
        assert!(hif4_incremental().total_area() < base);
        assert!(nvfp4_incremental().total_area() < base);
    }

    #[test]
    fn multiplier_area_dominates_nvfp4_increment() {
        let n = nvfp4_incremental();
        let mul_blocks: f64 = n
            .blocks
            .iter()
            .filter(|b| b.name.contains("multiplier"))
            .map(Block::total_area)
            .sum();
        assert!(mul_blocks > 0.5 * n.total_area());
    }

    #[test]
    fn block_accounting() {
        let r = hif4_incremental();
        let manual: f64 = r.blocks.iter().map(|b| b.area * b.count as f64).sum();
        assert_eq!(r.total_area(), manual);
        // Activity 1.0 ⇒ power == area for each block.
        assert_eq!(r.total_power(), r.total_area());
    }

    #[test]
    fn report_has_all_rows() {
        let rows = report_rows();
        assert_eq!(rows.len(), 5);
        assert!(rows.iter().all(|(_, a, p)| *a > 0.0 && *p > 0.0));
    }
}
