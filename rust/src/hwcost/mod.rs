//! Analytic hardware area/power model for the 64-length dot-product PE
//! (§III.B): derives the paper's "HiF4 occupies ≈1/3 the incremental area
//! of NVFP4 and reduces power by ≈10 %" from gate-level first principles
//! rather than hardcoding the numbers.
//!
//! Model (standard architecture-class estimates):
//! * an n×m-bit array multiplier costs ∝ n·m gate units (partial-product
//!   array dominates);
//! * a w-bit adder costs ∝ w;
//! * a w-bit shifter (1-of-k barrel stage) costs ∝ w·log2(k);
//! * dynamic power of a block ∝ its area × an activity factor (datapath
//!   blocks toggle every cycle, so activity ≈ 1 for all blocks here).
//!
//! The 4-bit BFP paths are *added to an existing PE* that already serves
//! FP16/BF16 and INT8/FP8 — the 64 small element multipliers and the
//! integer reduction tree are shared with the INT8 mode, so the
//! **incremental** area of each format is only what its metadata scaling
//! demands: scale multipliers, large integer multipliers, extra shift/
//! accumulation logic (the paper's accounting; Fig 4).
//!
//! Software note: the crate's packed QGEMM ([`crate::dotprod::quant_tensor`])
//! is a CPU *schedule* of this same Fig 4 datapath — the identical
//! element multiplies and integer-tree adds per 64-length dot, with the
//! micro-exponent shifts pre-applied at pack time. It changes nothing
//! about the hardware inventory, so these tables remain the area/power
//! story no matter which software kernel backend ran.

pub mod pe;

pub use pe::{
    hif4_incremental, nvfp4_incremental, shared_base, AreaReport, Block, PowerReport,
};

/// Area of an n×m array multiplier, in gate units.
#[inline]
pub fn mul_area(n: u32, m: u32) -> f64 {
    (n as f64) * (m as f64)
}

/// Area of a w-bit adder.
#[inline]
pub fn add_area(w: u32) -> f64 {
    w as f64
}

/// Area of a w-bit shifter with `stages` barrel stages.
#[inline]
pub fn shift_area(w: u32, stages: u32) -> f64 {
    (w as f64) * (stages as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_scale_correctly() {
        assert_eq!(mul_area(5, 5), 25.0);
        assert!(mul_area(13, 7) > mul_area(5, 5));
        assert_eq!(add_area(17), 17.0);
        assert_eq!(shift_area(13, 2), 26.0);
    }

    #[test]
    fn paper_area_claim_one_third() {
        // §III.B: "HiF4 occupies only approximately one-third the
        // incremental area of NVFP4".
        let h = hif4_incremental().total_area();
        let n = nvfp4_incremental().total_area();
        let ratio = n / h;
        assert!(
            (2.4..=4.0).contains(&ratio),
            "incremental area ratio should be ≈3×, got {ratio:.2} (hif4={h}, nvfp4={n})"
        );
    }

    #[test]
    fn paper_power_claim_ten_percent() {
        // §III.B: "reduces the power consumption by about 10%" — measured on
        // the whole PE (shared base + increment), activity-weighted.
        let base = shared_base().total_power();
        let h = base + hif4_incremental().total_power();
        let n = base + nvfp4_incremental().total_power();
        let reduction = 1.0 - h / n;
        assert!(
            (0.05..=0.20).contains(&reduction),
            "power reduction should be ≈10%, got {:.1}% (hif4={h}, nvfp4={n})",
            reduction * 100.0
        );
    }
}
