//! Quantized GEMM over packed units — ties the PE flows (Fig 4) to whole
//! matrix multiplications and cross-checks them against the dequantize-then-
//! f32-gemm "simulated quantization" path the LLM experiments use.
//!
//! Layout: the reduction (K) axis is blocked into format groups; `A` rows
//! and `B` columns are quantized independently per K-block, mirroring how
//! activations (row-major) and weights (stored transposed, out×in) are
//! blocked on real hardware.

use super::{hif4_flow, nvfp4_flow};
use crate::formats::hif4::{self, HiF4Unit};
use crate::formats::nvfp4::{self, Nvfp4Group};
use crate::formats::rounding::RoundMode;
use crate::tensor::Matrix;

/// A matrix quantized into HiF4 units along its rows (row-major; each row
/// padded to a multiple of 64).
pub struct HiF4Matrix {
    pub rows: usize,
    pub cols: usize,
    pub units_per_row: usize,
    pub units: Vec<HiF4Unit>,
}

impl HiF4Matrix {
    /// Quantize a row-major matrix along its rows.
    pub fn quantize(m: &Matrix, mode: RoundMode) -> HiF4Matrix {
        let upr = m.cols.div_ceil(hif4::GROUP);
        let mut units = Vec::with_capacity(m.rows * upr);
        let mut buf = vec![0f32; hif4::GROUP];
        for r in 0..m.rows {
            let row = m.row(r);
            for u in 0..upr {
                let start = u * hif4::GROUP;
                let end = (start + hif4::GROUP).min(m.cols);
                buf[..end - start].copy_from_slice(&row[start..end]);
                buf[end - start..].fill(0.0);
                units.push(hif4::quantize(&buf, mode));
            }
        }
        HiF4Matrix { rows: m.rows, cols: m.cols, units_per_row: upr, units }
    }

    /// Dequantize back to a dense matrix (zero-padding trimmed).
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let mut buf = [0f32; hif4::GROUP];
        for r in 0..self.rows {
            for u in 0..self.units_per_row {
                self.units[r * self.units_per_row + u].decode_all(&mut buf);
                let start = u * hif4::GROUP;
                let end = (start + hif4::GROUP).min(self.cols);
                m.row_mut(r)[start..end].copy_from_slice(&buf[..end - start]);
            }
        }
        m
    }

    #[inline]
    pub fn row_units(&self, r: usize) -> &[HiF4Unit] {
        &self.units[r * self.units_per_row..(r + 1) * self.units_per_row]
    }
}

/// A matrix quantized into NVFP4 groups along its rows.
pub struct Nvfp4Matrix {
    pub rows: usize,
    pub cols: usize,
    pub groups_per_row: usize,
    pub groups: Vec<Nvfp4Group>,
}

impl Nvfp4Matrix {
    pub fn quantize(m: &Matrix, mode: RoundMode) -> Nvfp4Matrix {
        let gpr = m.cols.div_ceil(nvfp4::GROUP);
        let mut groups = Vec::with_capacity(m.rows * gpr);
        let mut buf = vec![0f32; nvfp4::GROUP];
        for r in 0..m.rows {
            let row = m.row(r);
            for g in 0..gpr {
                let start = g * nvfp4::GROUP;
                let end = (start + nvfp4::GROUP).min(m.cols);
                buf[..end - start].copy_from_slice(&row[start..end]);
                buf[end - start..].fill(0.0);
                groups.push(nvfp4::quantize(&buf, mode));
            }
        }
        Nvfp4Matrix { rows: m.rows, cols: m.cols, groups_per_row: gpr, groups }
    }

    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let mut buf = [0f32; nvfp4::GROUP];
        for r in 0..self.rows {
            for g in 0..self.groups_per_row {
                self.groups[r * self.groups_per_row + g].decode_all(&mut buf);
                let start = g * nvfp4::GROUP;
                let end = (start + nvfp4::GROUP).min(self.cols);
                m.row_mut(r)[start..end].copy_from_slice(&buf[..end - start]);
            }
        }
        m
    }

    #[inline]
    pub fn row_groups(&self, r: usize) -> &[Nvfp4Group] {
        &self.groups[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }
}

/// `C = A · Bᵀ` where both operands are HiF4-quantized along the K axis and
/// every 64-length slice runs through the bit-exact PE flow.
pub fn hif4_gemm_bt(a: &HiF4Matrix, b_t: &HiF4Matrix) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    let mut c = Matrix::zeros(a.rows, b_t.rows);
    for i in 0..a.rows {
        let au = a.row_units(i);
        for j in 0..b_t.rows {
            let bu = b_t.row_units(j);
            let mut acc = 0f64;
            for (ua, ub) in au.iter().zip(bu) {
                acc += hif4_flow::dot(ua, ub);
            }
            c.data[i * b_t.rows + j] = acc as f32;
        }
    }
    c
}

/// `C = A · Bᵀ` with NVFP4 operands; K-groups run through the 64-length PE
/// four at a time (tail PEs fall back to group-by-group partials, which is
/// numerically identical since the flow is exact).
pub fn nvfp4_gemm_bt(a: &Nvfp4Matrix, b_t: &Nvfp4Matrix) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    let mut c = Matrix::zeros(a.rows, b_t.rows);
    for i in 0..a.rows {
        let ag = a.row_groups(i);
        for j in 0..b_t.rows {
            let bg = b_t.row_groups(j);
            let mut acc = 0f64;
            let mut g = 0;
            while g + nvfp4_flow::GROUPS_PER_PE <= ag.len() {
                acc += nvfp4_flow::dot64(
                    &ag[g..g + nvfp4_flow::GROUPS_PER_PE],
                    &bg[g..g + nvfp4_flow::GROUPS_PER_PE],
                );
                g += nvfp4_flow::GROUPS_PER_PE;
            }
            while g < ag.len() {
                acc += nvfp4_flow::dot64_dequant_ref(
                    core::slice::from_ref(&ag[g]),
                    core::slice::from_ref(&bg[g]),
                );
                g += 1;
            }
            c.data[i * b_t.rows + j] = acc as f32;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gemm;
    use crate::tensor::rng::Rng;

    #[test]
    fn hif4_qgemm_equals_dequantized_f32_gemm() {
        let mut rng = Rng::seed(301);
        let a = Matrix::randn(5, 130, 1.0, &mut rng); // non-multiple of 64
        let b = Matrix::randn(7, 130, 1.0, &mut rng);
        let qa = HiF4Matrix::quantize(&a, RoundMode::NearestEven);
        let qb = HiF4Matrix::quantize(&b, RoundMode::NearestEven);
        let via_pe = hif4_gemm_bt(&qa, &qb);
        let via_dequant = gemm::matmul_bt(&qa.dequantize(), &qb.dequantize());
        // f64 PE accumulation vs f32 gemm accumulation: allow f32 summation
        // noise proportional to the reduction length.
        for (x, y) in via_pe.data.iter().zip(&via_dequant.data) {
            assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn nvfp4_qgemm_equals_dequantized_f32_gemm() {
        let mut rng = Rng::seed(302);
        let a = Matrix::randn(4, 72, 1.0, &mut rng); // 4.5 groups per row
        let b = Matrix::randn(6, 72, 1.0, &mut rng);
        let qa = Nvfp4Matrix::quantize(&a, RoundMode::NearestEven);
        let qb = Nvfp4Matrix::quantize(&b, RoundMode::NearestEven);
        let via_pe = nvfp4_gemm_bt(&qa, &qb);
        let via_dequant = gemm::matmul_bt(&qa.dequantize(), &qb.dequantize());
        for (x, y) in via_pe.data.iter().zip(&via_dequant.data) {
            assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn quantize_dequantize_matches_scheme_path() {
        // The packed-matrix path and the flat QuantScheme path must agree.
        let mut rng = Rng::seed(303);
        let m = Matrix::randn(3, 100, 0.5, &mut rng);
        let packed = HiF4Matrix::quantize(&m, RoundMode::NearestEven).dequantize();
        let scheme = crate::formats::QuantScheme::direct(crate::formats::Format::HiF4);
        for r in 0..m.rows {
            let flat = scheme.quant_dequant_vec(m.row(r));
            assert_eq!(packed.row(r), &flat[..], "row {r}");
        }
    }
}
