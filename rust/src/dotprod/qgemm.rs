//! Quantized GEMM over packed units — ties the PE flows (Fig 4) to whole
//! matrix multiplications and cross-checks them against the dequantize-then-
//! f32-gemm "simulated quantization" path the LLM experiments use.
//!
//! Layout: the reduction (K) axis is blocked into format groups; `A` rows
//! and `B` columns are quantized independently per K-block, mirroring how
//! activations (row-major) and weights (stored transposed, out×in) are
//! blocked on real hardware.
//!
//! ## Parallel blocked execution
//!
//! Quantization and the GEMMs are row-parallel: output rows fan out over
//! contiguous bands via [`crate::util::threadpool::parallel_row_bands`],
//! and within a band the kernels are cache-blocked — `JB` B-rows × `UB`
//! K-units panels stay L1-hot while a band streams through its A rows.
//! Every (i, j) accumulator still sums its unit dot products in ascending
//! K order on a single thread, so results are **bit-identical** for every
//! thread count (asserted by `tests/parallel_parity.rs`); the `*_threads`
//! variants take an explicit count, the plain names use the process knob.
//!
//! ## Kernel backends
//!
//! The default entry points ([`hif4_gemm_bt`], [`nvfp4_gemm_bt`] and their
//! `_threads` variants) dispatch on the process-wide
//! [`super::kernel`] selector (`HIF4_KERNEL` env / `--kernel` CLI):
//!
//! * **`Flow`** — the reference path: every unit pair runs through the
//!   bit-exact PE flow, re-extracting nibbles and micro-exponents per
//!   output element (O(M·N·K) decode work).
//! * **`Packed`** (default) — the fast path: operands are packed once
//!   into decode-once integer planes ([`super::packed`], O(M·K + N·K))
//!   and the inner loop is a straight `i8` dot with one scale fixup per
//!   unit.
//!
//! Both backends produce **bit-identical** matrices (pinned by
//! `tests/packed_parity.rs`), so the selector is a pure performance knob.

use super::packed::{
    hif4_gemm_bt_packed_threads, nvfp4_gemm_bt_packed_threads, PackedHiF4Matrix,
    PackedNvfp4Matrix,
};
use super::{hif4_flow, nvfp4_flow, Kernel};
use crate::formats::hif4::{self, HiF4Unit};
use crate::formats::nvfp4::{self, Nvfp4Group};
use crate::formats::rounding::RoundMode;
use crate::tensor::Matrix;
use crate::util::threadpool::{self, parallel_row_bands};

/// B-rows per cache block of the quantized GEMM kernels.
pub(crate) const JB: usize = 16;
/// K-units per cache block (64-element HiF4 units / 16-element NVFP4
/// groups; a multiple of [`nvfp4_flow::GROUPS_PER_PE`] so PE boundaries
/// never straddle a block edge).
pub(crate) const UB: usize = 16;

/// A matrix quantized into HiF4 units along its rows (row-major; each row
/// padded to a multiple of 64).
#[derive(Debug, Clone)]
pub struct HiF4Matrix {
    pub rows: usize,
    pub cols: usize,
    pub units_per_row: usize,
    pub units: Vec<HiF4Unit>,
}

impl HiF4Matrix {
    /// Quantize a row-major matrix along its rows (row-parallel with the
    /// process-default thread count; rows quantize independently, so the
    /// result is identical for any count).
    pub fn quantize(m: &Matrix, mode: RoundMode) -> HiF4Matrix {
        let work = m.rows * m.cols * threadpool::QUANT_WORK_PER_ELEM;
        Self::quantize_threads(m, mode, threadpool::threads_for(work))
    }

    /// [`HiF4Matrix::quantize`] with an explicit thread count.
    pub fn quantize_threads(m: &Matrix, mode: RoundMode, threads: usize) -> HiF4Matrix {
        let upr = m.cols.div_ceil(hif4::GROUP);
        if m.rows == 0 || upr == 0 {
            return HiF4Matrix { rows: m.rows, cols: m.cols, units_per_row: upr, units: Vec::new() };
        }
        let zero = hif4::quantize(&[0f32; hif4::GROUP], mode);
        let mut units = vec![zero; m.rows * upr];
        parallel_row_bands(&mut units, upr, threads, |first_row, band| {
            let mut buf = [0f32; hif4::GROUP];
            for (i, urow) in band.chunks_mut(upr).enumerate() {
                let row = m.row(first_row + i);
                for (u, unit) in urow.iter_mut().enumerate() {
                    let start = u * hif4::GROUP;
                    let end = (start + hif4::GROUP).min(m.cols);
                    buf[..end - start].copy_from_slice(&row[start..end]);
                    buf[end - start..].fill(0.0);
                    *unit = hif4::quantize(&buf, mode);
                }
            }
        });
        HiF4Matrix { rows: m.rows, cols: m.cols, units_per_row: upr, units }
    }

    /// Dequantize back to a dense matrix (zero-padding trimmed),
    /// row-parallel with the process-default thread count (rows decode
    /// independently, so the result is identical for any count).
    pub fn dequantize(&self) -> Matrix {
        let work = self.rows * self.cols * threadpool::DEQUANT_WORK_PER_ELEM;
        self.dequantize_threads(threadpool::threads_for(work))
    }

    /// Check the rows/cols/units bookkeeping is self-consistent: every row
    /// carries `cols.div_ceil(64)` units (ragged tails are zero-padded at
    /// quantize time — the single supported tail handling). Consumers that
    /// walk the unit plane (dequantize, the flow GEMMs, the packed pack)
    /// all call this, so a hand-built matrix with a missing or surplus
    /// tail unit fails loudly and identically everywhere instead of
    /// silently reading the wrong rows.
    pub(crate) fn assert_geometry(&self) {
        let need = self.cols.div_ceil(hif4::GROUP);
        assert_eq!(
            self.units_per_row, need,
            "HiF4Matrix geometry: {} cols need {} units/row (64-element groups, padded tail), \
             got {}",
            self.cols, need, self.units_per_row
        );
        assert_eq!(
            self.units.len(),
            self.rows * self.units_per_row,
            "HiF4Matrix geometry: {}×{} rows×units/row needs {} units, got {}",
            self.rows,
            self.units_per_row,
            self.rows * self.units_per_row,
            self.units.len()
        );
    }

    /// [`HiF4Matrix::dequantize`] with an explicit thread count.
    pub fn dequantize_threads(&self, threads: usize) -> Matrix {
        self.assert_geometry();
        let mut m = Matrix::zeros(self.rows, self.cols);
        if m.data.is_empty() {
            return m;
        }
        let upr = self.units_per_row;
        let cols = self.cols;
        parallel_row_bands(&mut m.data, cols, threads, |first_row, band| {
            let mut buf = [0f32; hif4::GROUP];
            for (i, row) in band.chunks_mut(cols).enumerate() {
                let units = self.row_units(first_row + i);
                for u in 0..upr {
                    units[u].decode_all(&mut buf);
                    let start = u * hif4::GROUP;
                    let end = (start + hif4::GROUP).min(cols);
                    row[start..end].copy_from_slice(&buf[..end - start]);
                }
            }
        });
        m
    }

    #[inline]
    pub fn row_units(&self, r: usize) -> &[HiF4Unit] {
        &self.units[r * self.units_per_row..(r + 1) * self.units_per_row]
    }
}

/// A matrix quantized into NVFP4 groups along its rows.
#[derive(Debug, Clone)]
pub struct Nvfp4Matrix {
    pub rows: usize,
    pub cols: usize,
    pub groups_per_row: usize,
    pub groups: Vec<Nvfp4Group>,
}

impl Nvfp4Matrix {
    /// Quantize a row-major matrix along its rows (row-parallel; identical
    /// for any thread count).
    pub fn quantize(m: &Matrix, mode: RoundMode) -> Nvfp4Matrix {
        let work = m.rows * m.cols * threadpool::QUANT_WORK_PER_ELEM;
        Self::quantize_threads(m, mode, threadpool::threads_for(work))
    }

    /// [`Nvfp4Matrix::quantize`] with an explicit thread count.
    pub fn quantize_threads(m: &Matrix, mode: RoundMode, threads: usize) -> Nvfp4Matrix {
        let gpr = m.cols.div_ceil(nvfp4::GROUP);
        if m.rows == 0 || gpr == 0 {
            return Nvfp4Matrix {
                rows: m.rows,
                cols: m.cols,
                groups_per_row: gpr,
                groups: Vec::new(),
            };
        }
        let zero = nvfp4::quantize(&[0f32; nvfp4::GROUP], mode);
        let mut groups = vec![zero; m.rows * gpr];
        parallel_row_bands(&mut groups, gpr, threads, |first_row, band| {
            let mut buf = [0f32; nvfp4::GROUP];
            for (i, grow) in band.chunks_mut(gpr).enumerate() {
                let row = m.row(first_row + i);
                for (g, group) in grow.iter_mut().enumerate() {
                    let start = g * nvfp4::GROUP;
                    let end = (start + nvfp4::GROUP).min(m.cols);
                    buf[..end - start].copy_from_slice(&row[start..end]);
                    buf[end - start..].fill(0.0);
                    *group = nvfp4::quantize(&buf, mode);
                }
            }
        });
        Nvfp4Matrix { rows: m.rows, cols: m.cols, groups_per_row: gpr, groups }
    }

    /// Dequantize back to a dense matrix, row-parallel like
    /// [`HiF4Matrix::dequantize`].
    pub fn dequantize(&self) -> Matrix {
        let work = self.rows * self.cols * threadpool::DEQUANT_WORK_PER_ELEM;
        self.dequantize_threads(threadpool::threads_for(work))
    }

    /// Twin of [`HiF4Matrix::assert_geometry`] for the 16-element NVFP4
    /// groups: same uniform padded-tail contract, same failure wording.
    pub(crate) fn assert_geometry(&self) {
        let need = self.cols.div_ceil(nvfp4::GROUP);
        assert_eq!(
            self.groups_per_row, need,
            "Nvfp4Matrix geometry: {} cols need {} groups/row (16-element groups, padded tail), \
             got {}",
            self.cols, need, self.groups_per_row
        );
        assert_eq!(
            self.groups.len(),
            self.rows * self.groups_per_row,
            "Nvfp4Matrix geometry: {}×{} rows×groups/row needs {} groups, got {}",
            self.rows,
            self.groups_per_row,
            self.rows * self.groups_per_row,
            self.groups.len()
        );
    }

    /// [`Nvfp4Matrix::dequantize`] with an explicit thread count.
    pub fn dequantize_threads(&self, threads: usize) -> Matrix {
        self.assert_geometry();
        let mut m = Matrix::zeros(self.rows, self.cols);
        if m.data.is_empty() {
            return m;
        }
        let gpr = self.groups_per_row;
        let cols = self.cols;
        parallel_row_bands(&mut m.data, cols, threads, |first_row, band| {
            let mut buf = [0f32; nvfp4::GROUP];
            for (i, row) in band.chunks_mut(cols).enumerate() {
                let groups = self.row_groups(first_row + i);
                for g in 0..gpr {
                    groups[g].decode_all(&mut buf);
                    let start = g * nvfp4::GROUP;
                    let end = (start + nvfp4::GROUP).min(cols);
                    row[start..end].copy_from_slice(&buf[..end - start]);
                }
            }
        });
        m
    }

    #[inline]
    pub fn row_groups(&self, r: usize) -> &[Nvfp4Group] {
        &self.groups[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }
}

/// `C = A · Bᵀ` where both operands are HiF4-quantized along the K axis.
/// Cache-blocked and row-parallel with the process-default thread count;
/// dispatches on the [`super::kernel`] backend (numerically inert — both
/// backends are bit-identical).
pub fn hif4_gemm_bt(a: &HiF4Matrix, b_t: &HiF4Matrix) -> Matrix {
    let work = a.rows * b_t.rows * a.cols;
    hif4_gemm_bt_threads(a, b_t, threadpool::threads_for(work))
}

/// [`hif4_gemm_bt`] with an explicit thread count — bit-identical for
/// every value (each output element accumulates its unit dots in ascending
/// K order on one thread).
pub fn hif4_gemm_bt_threads(a: &HiF4Matrix, b_t: &HiF4Matrix, threads: usize) -> Matrix {
    match super::kernel() {
        Kernel::Flow => hif4_gemm_bt_flow_threads(a, b_t, threads),
        Kernel::Packed => {
            // One-time O(M·K + N·K) pack, then the SWAR fast path; callers
            // holding operands across calls should pack once themselves
            // ([`PackedHiF4Matrix`]) to amortize even this.
            let pa = PackedHiF4Matrix::pack_threads(a, threads);
            let pb = PackedHiF4Matrix::pack_threads(b_t, threads);
            hif4_gemm_bt_packed_threads(&pa, &pb, threads)
        }
    }
}

/// The reference flow-kernel GEMM (process-default threads): every unit
/// pair runs through the bit-exact PE flow.
pub fn hif4_gemm_bt_flow(a: &HiF4Matrix, b_t: &HiF4Matrix) -> Matrix {
    let work = a.rows * b_t.rows * a.cols;
    hif4_gemm_bt_flow_threads(a, b_t, threadpool::threads_for(work))
}

/// [`hif4_gemm_bt_flow`] with an explicit thread count.
pub fn hif4_gemm_bt_flow_threads(a: &HiF4Matrix, b_t: &HiF4Matrix, threads: usize) -> Matrix {
    a.assert_geometry();
    b_t.assert_geometry();
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    let (n, upr) = (b_t.rows, a.units_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [0f64; JB];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            for i in 0..rows {
                let au = a.row_units(first_row + i);
                accs[..jb].fill(0.0);
                // K-blocked: a JB × UB panel of B units stays hot while the
                // A row streams; accumulation per (i, j) remains ascending-u.
                for u0 in (0..upr).step_by(UB) {
                    let u1 = (u0 + UB).min(upr);
                    let au_blk = &au[u0..u1];
                    for (jj, acc) in accs[..jb].iter_mut().enumerate() {
                        let bu_blk = &b_t.row_units(j0 + jj)[u0..u1];
                        for (ua, ub) in au_blk.iter().zip(bu_blk) {
                            *acc += hif4_flow::dot(ua, ub);
                        }
                    }
                }
                let crow = &mut band[i * n..(i + 1) * n];
                for (jj, acc) in accs[..jb].iter().enumerate() {
                    crow[j0 + jj] = *acc as f32;
                }
            }
        }
    });
    c
}

/// `C = A · Bᵀ` with NVFP4 operands; K-groups run through the 64-length PE
/// four at a time, and tail groups stay on the fixed-point path via
/// [`nvfp4_flow::dot_group`]. Cache-blocked and row-parallel like
/// [`hif4_gemm_bt`]; dispatches on the [`super::kernel`] backend.
pub fn nvfp4_gemm_bt(a: &Nvfp4Matrix, b_t: &Nvfp4Matrix) -> Matrix {
    let work = a.rows * b_t.rows * a.cols;
    nvfp4_gemm_bt_threads(a, b_t, threadpool::threads_for(work))
}

/// [`nvfp4_gemm_bt`] with an explicit thread count (bit-identical for
/// every value).
pub fn nvfp4_gemm_bt_threads(a: &Nvfp4Matrix, b_t: &Nvfp4Matrix, threads: usize) -> Matrix {
    match super::kernel() {
        Kernel::Flow => nvfp4_gemm_bt_flow_threads(a, b_t, threads),
        Kernel::Packed => {
            let pa = PackedNvfp4Matrix::pack_threads(a, threads);
            let pb = PackedNvfp4Matrix::pack_threads(b_t, threads);
            nvfp4_gemm_bt_packed_threads(&pa, &pb, threads)
        }
    }
}

/// The reference flow-kernel NVFP4 GEMM (process-default threads).
pub fn nvfp4_gemm_bt_flow(a: &Nvfp4Matrix, b_t: &Nvfp4Matrix) -> Matrix {
    let work = a.rows * b_t.rows * a.cols;
    nvfp4_gemm_bt_flow_threads(a, b_t, threadpool::threads_for(work))
}

/// [`nvfp4_gemm_bt_flow`] with an explicit thread count.
pub fn nvfp4_gemm_bt_flow_threads(a: &Nvfp4Matrix, b_t: &Nvfp4Matrix, threads: usize) -> Matrix {
    a.assert_geometry();
    b_t.assert_geometry();
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    const PE: usize = nvfp4_flow::GROUPS_PER_PE;
    // UB is a PE multiple, so full-PE dots never straddle a K block and the
    // blocked schedule issues exactly the same dot64/tail sequence as a
    // flat left-to-right walk.
    const _: () = assert!(UB % PE == 0);
    let (n, gpr) = (b_t.rows, a.groups_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [0f64; JB];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            for i in 0..rows {
                let ag = a.row_groups(first_row + i);
                accs[..jb].fill(0.0);
                for u0 in (0..gpr).step_by(UB) {
                    let u1 = (u0 + UB).min(gpr);
                    for (jj, acc) in accs[..jb].iter_mut().enumerate() {
                        let bg = b_t.row_groups(j0 + jj);
                        let mut g = u0;
                        while g + PE <= u1 {
                            *acc += nvfp4_flow::dot64(&ag[g..g + PE], &bg[g..g + PE]);
                            g += PE;
                        }
                        while g < u1 {
                            // Tail groups stay on the fixed-point path: one
                            // exact single-group integer partial.
                            *acc += nvfp4_flow::dot_group(&ag[g], &bg[g]);
                            g += 1;
                        }
                    }
                }
                let crow = &mut band[i * n..(i + 1) * n];
                for (jj, acc) in accs[..jb].iter().enumerate() {
                    crow[j0 + jj] = *acc as f32;
                }
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gemm;
    use crate::tensor::rng::Rng;

    #[test]
    fn hif4_qgemm_equals_dequantized_f32_gemm() {
        let mut rng = Rng::seed(301);
        let a = Matrix::randn(5, 130, 1.0, &mut rng); // non-multiple of 64
        let b = Matrix::randn(7, 130, 1.0, &mut rng);
        let qa = HiF4Matrix::quantize(&a, RoundMode::NearestEven);
        let qb = HiF4Matrix::quantize(&b, RoundMode::NearestEven);
        let via_pe = hif4_gemm_bt(&qa, &qb);
        let via_dequant = gemm::matmul_bt(&qa.dequantize(), &qb.dequantize());
        // f64 PE accumulation vs f32 gemm accumulation: allow f32 summation
        // noise proportional to the reduction length.
        for (x, y) in via_pe.data.iter().zip(&via_dequant.data) {
            assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn nvfp4_qgemm_equals_dequantized_f32_gemm() {
        let mut rng = Rng::seed(302);
        let a = Matrix::randn(4, 72, 1.0, &mut rng); // 4.5 groups per row
        let b = Matrix::randn(6, 72, 1.0, &mut rng);
        let qa = Nvfp4Matrix::quantize(&a, RoundMode::NearestEven);
        let qb = Nvfp4Matrix::quantize(&b, RoundMode::NearestEven);
        let via_pe = nvfp4_gemm_bt(&qa, &qb);
        let via_dequant = gemm::matmul_bt(&qa.dequantize(), &qb.dequantize());
        for (x, y) in via_pe.data.iter().zip(&via_dequant.data) {
            assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn quantize_dequantize_matches_scheme_path() {
        // The packed-matrix path and the flat QuantScheme path must agree.
        let mut rng = Rng::seed(303);
        let m = Matrix::randn(3, 100, 0.5, &mut rng);
        let packed = HiF4Matrix::quantize(&m, RoundMode::NearestEven).dequantize();
        let scheme = crate::formats::QuantScheme::direct(crate::formats::Format::HiF4);
        for r in 0..m.rows {
            let flat = scheme.quant_dequant_vec(m.row(r));
            assert_eq!(packed.row(r), &flat[..], "row {r}");
        }
    }
}
