//! Quantized GEMM over packed units — ties the PE flows (Fig 4) to whole
//! matrix multiplications and cross-checks them against the dequantize-then-
//! f32-gemm "simulated quantization" path the LLM experiments use.
//!
//! Layout: the reduction (K) axis is blocked into format groups; `A` rows
//! and `B` columns are quantized independently per K-block, mirroring how
//! activations (row-major) and weights (stored transposed, out×in) are
//! blocked on real hardware.
//!
//! ## Parallel blocked execution
//!
//! Quantization and the GEMMs are row-parallel: output rows fan out over
//! contiguous bands via [`crate::util::threadpool::parallel_row_bands`],
//! and within a band the kernels are cache-blocked — `JB` B-rows × `UB`
//! K-units panels stay L1-hot while a band streams through its A rows.
//! Every (i, j) accumulator still sums its unit dot products in ascending
//! K order on a single thread, so results are **bit-identical** for every
//! thread count (asserted by `tests/parallel_parity.rs`); the `*_threads`
//! variants take an explicit count, the plain names use the process knob.

use super::{hif4_flow, nvfp4_flow};
use crate::formats::hif4::{self, HiF4Unit};
use crate::formats::nvfp4::{self, Nvfp4Group};
use crate::formats::rounding::RoundMode;
use crate::tensor::Matrix;
use crate::util::threadpool::{self, parallel_row_bands};

/// B-rows per cache block of the quantized GEMM kernels.
const JB: usize = 16;
/// K-units per cache block (64-element HiF4 units / 16-element NVFP4
/// groups; a multiple of [`nvfp4_flow::GROUPS_PER_PE`] so PE boundaries
/// never straddle a block edge).
const UB: usize = 16;

/// A matrix quantized into HiF4 units along its rows (row-major; each row
/// padded to a multiple of 64).
pub struct HiF4Matrix {
    pub rows: usize,
    pub cols: usize,
    pub units_per_row: usize,
    pub units: Vec<HiF4Unit>,
}

impl HiF4Matrix {
    /// Quantize a row-major matrix along its rows (row-parallel with the
    /// process-default thread count; rows quantize independently, so the
    /// result is identical for any count).
    pub fn quantize(m: &Matrix, mode: RoundMode) -> HiF4Matrix {
        let work = m.rows * m.cols * threadpool::QUANT_WORK_PER_ELEM;
        Self::quantize_threads(m, mode, threadpool::threads_for(work))
    }

    /// [`HiF4Matrix::quantize`] with an explicit thread count.
    pub fn quantize_threads(m: &Matrix, mode: RoundMode, threads: usize) -> HiF4Matrix {
        let upr = m.cols.div_ceil(hif4::GROUP);
        if m.rows == 0 || upr == 0 {
            return HiF4Matrix { rows: m.rows, cols: m.cols, units_per_row: upr, units: Vec::new() };
        }
        let zero = hif4::quantize(&[0f32; hif4::GROUP], mode);
        let mut units = vec![zero; m.rows * upr];
        parallel_row_bands(&mut units, upr, threads, |first_row, band| {
            let mut buf = [0f32; hif4::GROUP];
            for (i, urow) in band.chunks_mut(upr).enumerate() {
                let row = m.row(first_row + i);
                for (u, unit) in urow.iter_mut().enumerate() {
                    let start = u * hif4::GROUP;
                    let end = (start + hif4::GROUP).min(m.cols);
                    buf[..end - start].copy_from_slice(&row[start..end]);
                    buf[end - start..].fill(0.0);
                    *unit = hif4::quantize(&buf, mode);
                }
            }
        });
        HiF4Matrix { rows: m.rows, cols: m.cols, units_per_row: upr, units }
    }

    /// Dequantize back to a dense matrix (zero-padding trimmed).
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let mut buf = [0f32; hif4::GROUP];
        for r in 0..self.rows {
            for u in 0..self.units_per_row {
                self.units[r * self.units_per_row + u].decode_all(&mut buf);
                let start = u * hif4::GROUP;
                let end = (start + hif4::GROUP).min(self.cols);
                m.row_mut(r)[start..end].copy_from_slice(&buf[..end - start]);
            }
        }
        m
    }

    #[inline]
    pub fn row_units(&self, r: usize) -> &[HiF4Unit] {
        &self.units[r * self.units_per_row..(r + 1) * self.units_per_row]
    }
}

/// A matrix quantized into NVFP4 groups along its rows.
pub struct Nvfp4Matrix {
    pub rows: usize,
    pub cols: usize,
    pub groups_per_row: usize,
    pub groups: Vec<Nvfp4Group>,
}

impl Nvfp4Matrix {
    /// Quantize a row-major matrix along its rows (row-parallel; identical
    /// for any thread count).
    pub fn quantize(m: &Matrix, mode: RoundMode) -> Nvfp4Matrix {
        let work = m.rows * m.cols * threadpool::QUANT_WORK_PER_ELEM;
        Self::quantize_threads(m, mode, threadpool::threads_for(work))
    }

    /// [`Nvfp4Matrix::quantize`] with an explicit thread count.
    pub fn quantize_threads(m: &Matrix, mode: RoundMode, threads: usize) -> Nvfp4Matrix {
        let gpr = m.cols.div_ceil(nvfp4::GROUP);
        if m.rows == 0 || gpr == 0 {
            return Nvfp4Matrix {
                rows: m.rows,
                cols: m.cols,
                groups_per_row: gpr,
                groups: Vec::new(),
            };
        }
        let zero = nvfp4::quantize(&[0f32; nvfp4::GROUP], mode);
        let mut groups = vec![zero; m.rows * gpr];
        parallel_row_bands(&mut groups, gpr, threads, |first_row, band| {
            let mut buf = [0f32; nvfp4::GROUP];
            for (i, grow) in band.chunks_mut(gpr).enumerate() {
                let row = m.row(first_row + i);
                for (g, group) in grow.iter_mut().enumerate() {
                    let start = g * nvfp4::GROUP;
                    let end = (start + nvfp4::GROUP).min(m.cols);
                    buf[..end - start].copy_from_slice(&row[start..end]);
                    buf[end - start..].fill(0.0);
                    *group = nvfp4::quantize(&buf, mode);
                }
            }
        });
        Nvfp4Matrix { rows: m.rows, cols: m.cols, groups_per_row: gpr, groups }
    }

    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let mut buf = [0f32; nvfp4::GROUP];
        for r in 0..self.rows {
            for g in 0..self.groups_per_row {
                self.groups[r * self.groups_per_row + g].decode_all(&mut buf);
                let start = g * nvfp4::GROUP;
                let end = (start + nvfp4::GROUP).min(self.cols);
                m.row_mut(r)[start..end].copy_from_slice(&buf[..end - start]);
            }
        }
        m
    }

    #[inline]
    pub fn row_groups(&self, r: usize) -> &[Nvfp4Group] {
        &self.groups[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }
}

/// `C = A · Bᵀ` where both operands are HiF4-quantized along the K axis and
/// every 64-length slice runs through the bit-exact PE flow. Cache-blocked
/// and row-parallel with the process-default thread count.
pub fn hif4_gemm_bt(a: &HiF4Matrix, b_t: &HiF4Matrix) -> Matrix {
    let work = a.rows * b_t.rows * a.cols;
    hif4_gemm_bt_threads(a, b_t, threadpool::threads_for(work))
}

/// [`hif4_gemm_bt`] with an explicit thread count — bit-identical for
/// every value (each output element accumulates its unit dots in ascending
/// K order on one thread).
pub fn hif4_gemm_bt_threads(a: &HiF4Matrix, b_t: &HiF4Matrix, threads: usize) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    let (n, upr) = (b_t.rows, a.units_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [0f64; JB];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            for i in 0..rows {
                let au = a.row_units(first_row + i);
                accs[..jb].fill(0.0);
                // K-blocked: a JB × UB panel of B units stays hot while the
                // A row streams; accumulation per (i, j) remains ascending-u.
                for u0 in (0..upr).step_by(UB) {
                    let u1 = (u0 + UB).min(upr);
                    let au_blk = &au[u0..u1];
                    for (jj, acc) in accs[..jb].iter_mut().enumerate() {
                        let bu_blk = &b_t.row_units(j0 + jj)[u0..u1];
                        for (ua, ub) in au_blk.iter().zip(bu_blk) {
                            *acc += hif4_flow::dot(ua, ub);
                        }
                    }
                }
                let crow = &mut band[i * n..(i + 1) * n];
                for (jj, acc) in accs[..jb].iter().enumerate() {
                    crow[j0 + jj] = *acc as f32;
                }
            }
        }
    });
    c
}

/// `C = A · Bᵀ` with NVFP4 operands; K-groups run through the 64-length PE
/// four at a time (tail PEs fall back to group-by-group partials, which is
/// numerically identical since the flow is exact). Cache-blocked and
/// row-parallel like [`hif4_gemm_bt`].
pub fn nvfp4_gemm_bt(a: &Nvfp4Matrix, b_t: &Nvfp4Matrix) -> Matrix {
    let work = a.rows * b_t.rows * a.cols;
    nvfp4_gemm_bt_threads(a, b_t, threadpool::threads_for(work))
}

/// [`nvfp4_gemm_bt`] with an explicit thread count (bit-identical for
/// every value).
pub fn nvfp4_gemm_bt_threads(a: &Nvfp4Matrix, b_t: &Nvfp4Matrix, threads: usize) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    const PE: usize = nvfp4_flow::GROUPS_PER_PE;
    // UB is a PE multiple, so full-PE dots never straddle a K block and the
    // blocked schedule issues exactly the same dot64/tail sequence as a
    // flat left-to-right walk.
    const _: () = assert!(UB % PE == 0);
    let (n, gpr) = (b_t.rows, a.groups_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [0f64; JB];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            for i in 0..rows {
                let ag = a.row_groups(first_row + i);
                accs[..jb].fill(0.0);
                for u0 in (0..gpr).step_by(UB) {
                    let u1 = (u0 + UB).min(gpr);
                    for (jj, acc) in accs[..jb].iter_mut().enumerate() {
                        let bg = b_t.row_groups(j0 + jj);
                        let mut g = u0;
                        while g + PE <= u1 {
                            *acc += nvfp4_flow::dot64(&ag[g..g + PE], &bg[g..g + PE]);
                            g += PE;
                        }
                        while g < u1 {
                            *acc += nvfp4_flow::dot64_dequant_ref(
                                core::slice::from_ref(&ag[g]),
                                core::slice::from_ref(&bg[g]),
                            );
                            g += 1;
                        }
                    }
                }
                let crow = &mut band[i * n..(i + 1) * n];
                for (jj, acc) in accs[..jb].iter().enumerate() {
                    crow[j0 + jj] = *acc as f32;
                }
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gemm;
    use crate::tensor::rng::Rng;

    #[test]
    fn hif4_qgemm_equals_dequantized_f32_gemm() {
        let mut rng = Rng::seed(301);
        let a = Matrix::randn(5, 130, 1.0, &mut rng); // non-multiple of 64
        let b = Matrix::randn(7, 130, 1.0, &mut rng);
        let qa = HiF4Matrix::quantize(&a, RoundMode::NearestEven);
        let qb = HiF4Matrix::quantize(&b, RoundMode::NearestEven);
        let via_pe = hif4_gemm_bt(&qa, &qb);
        let via_dequant = gemm::matmul_bt(&qa.dequantize(), &qb.dequantize());
        // f64 PE accumulation vs f32 gemm accumulation: allow f32 summation
        // noise proportional to the reduction length.
        for (x, y) in via_pe.data.iter().zip(&via_dequant.data) {
            assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn nvfp4_qgemm_equals_dequantized_f32_gemm() {
        let mut rng = Rng::seed(302);
        let a = Matrix::randn(4, 72, 1.0, &mut rng); // 4.5 groups per row
        let b = Matrix::randn(6, 72, 1.0, &mut rng);
        let qa = Nvfp4Matrix::quantize(&a, RoundMode::NearestEven);
        let qb = Nvfp4Matrix::quantize(&b, RoundMode::NearestEven);
        let via_pe = nvfp4_gemm_bt(&qa, &qb);
        let via_dequant = gemm::matmul_bt(&qa.dequantize(), &qb.dequantize());
        for (x, y) in via_pe.data.iter().zip(&via_dequant.data) {
            assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn quantize_dequantize_matches_scheme_path() {
        // The packed-matrix path and the flat QuantScheme path must agree.
        let mut rng = Rng::seed(303);
        let m = Matrix::randn(3, 100, 0.5, &mut rng);
        let packed = HiF4Matrix::quantize(&m, RoundMode::NearestEven).dequantize();
        let scheme = crate::formats::QuantScheme::direct(crate::formats::Format::HiF4);
        for r in 0..m.rows {
            let flat = scheme.quant_dequant_vec(m.row(r));
            assert_eq!(packed.row(r), &flat[..], "row {r}");
        }
    }
}
