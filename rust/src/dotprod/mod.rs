//! Fixed-point dot-product compute flows (§III.B, Fig 4).
//!
//! A matrix-compute PE performs a 64-length dot product + accumulation. For
//! 4-bit BFP formats at 2× the 8-bit rate, both Tensor Cores and Cube Cores
//! use 64-wide PEs:
//!
//! * **HiF4** — one unit pair fills the PE (group size 64). Level-3
//!   micro-exponents are absorbed into the elements before multiplication
//!   (4-bit S1P2 → 5-bit S2P2 integers); the 64 products reduce **entirely
//!   in integer arithmetic** (level-2 micro-exponents are left-shifts) down
//!   to a single S12P4 integer, which meets *one* small FP multiplier
//!   (E6M2×E6M2) and *one* large integer multiplier at the very end.
//! * **NVFP4** — four group pairs are needed (group size 16). Integer
//!   reduction stops at four S10P2 partials; each needs its own small FP
//!   multiplier (E4M3×E4M3) and large integer multiplier, and the final
//!   4-way accumulation runs in floating point.
//!
//! Everything here is **bit-exact**: the integer datapaths are checked
//! against the dequantized-f64 dot product (they agree exactly because every
//! quantized value is a small dyadic rational times its scales).
//!
//! Three software *schedules* of the same datapaths exist: the
//! element-wise flow kernels above (the reference), the decode-once packed
//! operand planes with a scalar inner dot, and the SIMD-tiled microkernel
//! over the same planes (the fast path — explicit AVX2 on `x86_64`
//! machines that have it, a portable unrolled-scalar microkernel
//! everywhere else; [`simd_isa`] reports which was detected at startup).
//! All live behind the **unified quantized-tensor API** of
//! [`quant_tensor`] — one [`QuantizedMatrix`] / [`PackedQuantizedMatrix`]
//! surface over all five block formats, with the process-wide [`kernel`]
//! selector picking which schedule [`QuantizedMatrix::qgemm_bt`] runs; all
//! backends are bit-identical, so it is purely a performance knob.

pub mod hif4_flow;
pub mod nvfp4_flow;
pub mod quant_tensor;

pub use quant_tensor::{BlockFormat, PackedQuantizedMatrix, QuantizedMatrix};

use std::sync::atomic::{AtomicU8, Ordering};

/// Which software schedule the quantized GEMM entry points run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Reference: every group pair through the element-wise PE flow
    /// (re-decodes nibbles/micro-exponents per output element).
    Flow,
    /// Decode-once integer operand planes
    /// ([`quant_tensor::PackedQuantMat`]) with a straight scalar `i8`
    /// inner dot — the portable baseline of the plane schedule.
    Packed,
    /// Fast path (default): the same packed planes driven by the
    /// register-tiled SIMD microkernel — explicit AVX2 intrinsics where
    /// [`simd_isa`] detected them at startup, the unrolled-scalar
    /// microkernel elsewhere. Bit-identical to [`Kernel::Packed`] and
    /// [`Kernel::Flow`] on every format.
    Simd,
}

impl Kernel {
    /// Canonical lower-case label — the `HIF4_KERNEL` / `--kernel`
    /// spelling and the bench-JSON key.
    pub fn label(self) -> &'static str {
        match self {
            Kernel::Flow => "flow",
            Kernel::Packed => "packed",
            Kernel::Simd => "simd",
        }
    }
}

/// Process-wide kernel-backend override; 0 = not resolved yet.
static KERNEL: AtomicU8 = AtomicU8::new(0);

const KERNEL_FLOW: u8 = 1;
const KERNEL_PACKED: u8 = 2;
const KERNEL_SIMD: u8 = 3;

fn kernel_from_tag(tag: u8) -> Kernel {
    match tag {
        KERNEL_FLOW => Kernel::Flow,
        KERNEL_PACKED => Kernel::Packed,
        _ => Kernel::Simd,
    }
}

/// The process-wide kernel backend: `HIF4_KERNEL` (`simd` / `packed` /
/// `flow`) if set, else [`Kernel::Simd`] — whose lane ISA is resolved
/// once at startup by [`simd_isa`]; override with [`set_kernel`] (the
/// CLI exposes `--kernel`). All backends produce bit-identical matrices,
/// so this only changes throughput.
pub fn kernel() -> Kernel {
    let tag = KERNEL.load(Ordering::Relaxed);
    if tag != 0 {
        return kernel_from_tag(tag);
    }
    let resolved = match std::env::var("HIF4_KERNEL").ok().as_deref() {
        Some("flow") => KERNEL_FLOW,
        Some("packed") => KERNEL_PACKED,
        Some("simd") | None => KERNEL_SIMD,
        Some(other) => {
            // A perf knob that silently ignores typos would corrupt
            // measurements; warn loudly (once — the resolution is cached)
            // and run the default. The CLI's `--kernel` rejects outright.
            eprintln!(
                "warning: unrecognized HIF4_KERNEL={other:?} \
                 (expected \"simd\", \"packed\" or \"flow\"); using simd"
            );
            KERNEL_SIMD
        }
    };
    // Cache only if still unset so a racing set_kernel() is never
    // clobbered (same pattern as threadpool::threads).
    match KERNEL.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => kernel_from_tag(resolved),
        Err(current) => kernel_from_tag(current),
    }
}

/// Override the process-wide kernel backend.
pub fn set_kernel(k: Kernel) {
    let v = match k {
        Kernel::Flow => KERNEL_FLOW,
        Kernel::Packed => KERNEL_PACKED,
        Kernel::Simd => KERNEL_SIMD,
    };
    KERNEL.store(v, Ordering::Relaxed);
}

/// Which lane ISA the [`Kernel::Simd`] backend's microkernel runs on.
/// Resolved exactly once per process by runtime CPU-feature detection
/// ([`simd_isa`]); both ISAs are exact, so this never changes results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdIsa {
    /// `x86_64` AVX2: 16-lane `i8→i16` widening + `vpmaddwd`
    /// multiply-accumulate (no saturating instruction anywhere).
    Avx2,
    /// The portable unrolled-scalar microkernel (four independent
    /// accumulator chains) — any architecture, no special CPU features.
    Portable,
}

/// Cached [`SimdIsa`] resolution; 0 = not detected yet.
static SIMD_ISA: AtomicU8 = AtomicU8::new(0);

const ISA_AVX2: u8 = 1;
const ISA_PORTABLE: u8 = 2;

#[cfg(target_arch = "x86_64")]
fn detect_simd_isa() -> SimdIsa {
    if std::arch::is_x86_feature_detected!("avx2") {
        SimdIsa::Avx2
    } else {
        SimdIsa::Portable
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_simd_isa() -> SimdIsa {
    SimdIsa::Portable
}

/// The lane ISA the SIMD backend dispatches to: AVX2 when the CPU
/// reports it (checked once, result cached for the process lifetime),
/// the portable microkernel otherwise. Purely a throughput property —
/// the parity suites pin both ISAs bit-identical to the scalar kernels.
pub fn simd_isa() -> SimdIsa {
    match SIMD_ISA.load(Ordering::Relaxed) {
        ISA_AVX2 => return SimdIsa::Avx2,
        ISA_PORTABLE => return SimdIsa::Portable,
        _ => {}
    }
    let detected = detect_simd_isa();
    let tag = match detected {
        SimdIsa::Avx2 => ISA_AVX2,
        SimdIsa::Portable => ISA_PORTABLE,
    };
    SIMD_ISA.store(tag, Ordering::Relaxed);
    detected
}

/// Lower-case label of the detected [`simd_isa`] (`"avx2"` /
/// `"portable"`) — printed by `hif4 info` and the benches, and asserted
/// by CI's `HIF4_REQUIRE_SIMD` guard so the AVX2 path can never compile
/// out silently.
pub fn simd_isa_label() -> &'static str {
    match simd_isa() {
        SimdIsa::Avx2 => "avx2",
        SimdIsa::Portable => "portable",
    }
}

/// Datapath statistics a flow reports — consumed by [`crate::hwcost`] and
/// the Fig-4 bench.
///
/// These counts describe the *hardware datapath* of Fig 4. The software
/// packed kernel ([`quant_tensor`]) is a different **schedule** of the
/// same datapath — it performs exactly the same element multiplies and
/// integer-tree adds per 64-length dot (the micro-exponent shifts are
/// merely pre-applied at pack time), so these inventories, and the
/// [`crate::hwcost`] area/power tables derived from them, remain the
/// hardware story regardless of which software backend ran.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowStats {
    /// 5-bit × 5-bit element multipliers (shared with the INT8 path).
    pub small_int_muls: usize,
    /// Small floating-point scale multipliers (metadata × metadata).
    pub small_fp_muls: usize,
    /// Large integer multipliers (scale significand × reduced integer).
    pub large_int_muls: usize,
    /// Floating-point adders in the final accumulation.
    pub fp_adds: usize,
    /// Integer adders in the reduction tree (count of 2-input adds).
    pub int_adds: usize,
    /// Width in bits of the final integer(s) the reduction produces.
    pub final_int_bits: u32,
}

#[cfg(test)]
mod tests {
    use super::hif4_flow;
    use super::nvfp4_flow;

    // NOTE: the set_kernel/kernel round-trip is asserted inside
    // `model::transformer`'s kernel-invariance test — exactly one test
    // mutates the process-wide knob, so readback can never race. Every
    // other consumer only *reads* it, and since all backends are
    // bit-identical, a concurrently flipped knob never changes results.

    #[test]
    fn kernel_labels_and_simd_isa_resolve() {
        use super::{simd_isa, simd_isa_label, Kernel, SimdIsa};
        assert_eq!(Kernel::Flow.label(), "flow");
        assert_eq!(Kernel::Packed.label(), "packed");
        assert_eq!(Kernel::Simd.label(), "simd");
        // Detection is cached: repeated reads agree, and the label is the
        // canonical spelling of the resolved ISA.
        let first = simd_isa();
        assert_eq!(first, simd_isa());
        let want = match first {
            SimdIsa::Avx2 => "avx2",
            SimdIsa::Portable => "portable",
        };
        assert_eq!(simd_isa_label(), want);
    }

    #[test]
    fn fig4_multiplier_elimination() {
        // "HiF4 eliminates six multipliers" — 1 small FP + 1 large INT vs
        // 4 small FP + 4 large INT.
        let h = hif4_flow::stats();
        let n = nvfp4_flow::stats();
        assert_eq!(h.small_fp_muls, 1);
        assert_eq!(h.large_int_muls, 1);
        assert_eq!(n.small_fp_muls, 4);
        assert_eq!(n.large_int_muls, 4);
        let eliminated =
            (n.small_fp_muls + n.large_int_muls) - (h.small_fp_muls + h.large_int_muls);
        assert_eq!(eliminated, 6);
        // Both share the 64 small element multipliers.
        assert_eq!(h.small_int_muls, 64);
        assert_eq!(n.small_int_muls, 64);
        // NVFP4's final accumulation is floating-point; HiF4's is not.
        assert!(h.fp_adds == 0 && n.fp_adds == 3);
    }
}
