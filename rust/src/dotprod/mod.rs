//! Fixed-point dot-product compute flows (§III.B, Fig 4).
//!
//! A matrix-compute PE performs a 64-length dot product + accumulation. For
//! 4-bit BFP formats at 2× the 8-bit rate, both Tensor Cores and Cube Cores
//! use 64-wide PEs:
//!
//! * **HiF4** — one unit pair fills the PE (group size 64). Level-3
//!   micro-exponents are absorbed into the elements before multiplication
//!   (4-bit S1P2 → 5-bit S2P2 integers); the 64 products reduce **entirely
//!   in integer arithmetic** (level-2 micro-exponents are left-shifts) down
//!   to a single S12P4 integer, which meets *one* small FP multiplier
//!   (E6M2×E6M2) and *one* large integer multiplier at the very end.
//! * **NVFP4** — four group pairs are needed (group size 16). Integer
//!   reduction stops at four S10P2 partials; each needs its own small FP
//!   multiplier (E4M3×E4M3) and large integer multiplier, and the final
//!   4-way accumulation runs in floating point.
//!
//! Everything here is **bit-exact**: the integer datapaths are checked
//! against the dequantized-f64 dot product (they agree exactly because every
//! quantized value is a small dyadic rational times its scales).
//!
//! Two software *schedules* of the same datapaths exist: the element-wise
//! flow kernels above (the reference) and the decode-once packed operand
//! planes (the fast path). Both live behind the **unified quantized-tensor
//! API** of [`quant_tensor`] — one [`QuantizedMatrix`] /
//! [`PackedQuantizedMatrix`] surface over all five block formats, with the
//! process-wide [`kernel`] selector picking which schedule
//! [`QuantizedMatrix::qgemm_bt`] runs; both are bit-identical, so it is
//! purely a performance knob.

pub mod hif4_flow;
pub mod nvfp4_flow;
pub mod quant_tensor;

pub use quant_tensor::{BlockFormat, PackedQuantizedMatrix, QuantizedMatrix};

use std::sync::atomic::{AtomicU8, Ordering};

/// Which software schedule the quantized GEMM entry points run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Reference: every group pair through the element-wise PE flow
    /// (re-decodes nibbles/micro-exponents per output element).
    Flow,
    /// Fast path (default): decode-once integer operand planes
    /// ([`quant_tensor::PackedQuantMat`]) with a straight `i8` inner dot.
    Packed,
}

/// Process-wide kernel-backend override; 0 = not resolved yet.
static KERNEL: AtomicU8 = AtomicU8::new(0);

const KERNEL_FLOW: u8 = 1;
const KERNEL_PACKED: u8 = 2;

/// The process-wide kernel backend: `HIF4_KERNEL` (`flow` / `packed`) if
/// set, else [`Kernel::Packed`]; override with [`set_kernel`] (the CLI
/// exposes `--kernel`). Both backends produce bit-identical matrices, so
/// this only changes throughput.
pub fn kernel() -> Kernel {
    match KERNEL.load(Ordering::Relaxed) {
        KERNEL_FLOW => return Kernel::Flow,
        KERNEL_PACKED => return Kernel::Packed,
        _ => {}
    }
    let resolved = match std::env::var("HIF4_KERNEL").ok().as_deref() {
        Some("flow") => KERNEL_FLOW,
        Some("packed") | None => KERNEL_PACKED,
        Some(other) => {
            // A perf knob that silently ignores typos would corrupt
            // measurements; warn loudly (once — the resolution is cached)
            // and run the default. The CLI's `--kernel` rejects outright.
            eprintln!(
                "warning: unrecognized HIF4_KERNEL={other:?} \
                 (expected \"flow\" or \"packed\"); using packed"
            );
            KERNEL_PACKED
        }
    };
    // Cache only if still unset so a racing set_kernel() is never
    // clobbered (same pattern as threadpool::threads).
    match KERNEL.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => {}
        Err(current) => return if current == KERNEL_FLOW { Kernel::Flow } else { Kernel::Packed },
    }
    if resolved == KERNEL_FLOW {
        Kernel::Flow
    } else {
        Kernel::Packed
    }
}

/// Override the process-wide kernel backend.
pub fn set_kernel(k: Kernel) {
    let v = match k {
        Kernel::Flow => KERNEL_FLOW,
        Kernel::Packed => KERNEL_PACKED,
    };
    KERNEL.store(v, Ordering::Relaxed);
}

/// Datapath statistics a flow reports — consumed by [`crate::hwcost`] and
/// the Fig-4 bench.
///
/// These counts describe the *hardware datapath* of Fig 4. The software
/// packed kernel ([`quant_tensor`]) is a different **schedule** of the
/// same datapath — it performs exactly the same element multiplies and
/// integer-tree adds per 64-length dot (the micro-exponent shifts are
/// merely pre-applied at pack time), so these inventories, and the
/// [`crate::hwcost`] area/power tables derived from them, remain the
/// hardware story regardless of which software backend ran.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowStats {
    /// 5-bit × 5-bit element multipliers (shared with the INT8 path).
    pub small_int_muls: usize,
    /// Small floating-point scale multipliers (metadata × metadata).
    pub small_fp_muls: usize,
    /// Large integer multipliers (scale significand × reduced integer).
    pub large_int_muls: usize,
    /// Floating-point adders in the final accumulation.
    pub fp_adds: usize,
    /// Integer adders in the reduction tree (count of 2-input adds).
    pub int_adds: usize,
    /// Width in bits of the final integer(s) the reduction produces.
    pub final_int_bits: u32,
}

#[cfg(test)]
mod tests {
    use super::hif4_flow;
    use super::nvfp4_flow;

    // NOTE: the set_kernel/kernel round-trip is asserted inside
    // `model::transformer`'s kernel-invariance test — exactly one test
    // mutates the process-wide knob, so readback can never race. Every
    // other consumer only *reads* it, and since both backends are
    // bit-identical, a concurrently flipped knob never changes results.

    #[test]
    fn fig4_multiplier_elimination() {
        // "HiF4 eliminates six multipliers" — 1 small FP + 1 large INT vs
        // 4 small FP + 4 large INT.
        let h = hif4_flow::stats();
        let n = nvfp4_flow::stats();
        assert_eq!(h.small_fp_muls, 1);
        assert_eq!(h.large_int_muls, 1);
        assert_eq!(n.small_fp_muls, 4);
        assert_eq!(n.large_int_muls, 4);
        let eliminated =
            (n.small_fp_muls + n.large_int_muls) - (h.small_fp_muls + h.large_int_muls);
        assert_eq!(eliminated, 6);
        // Both share the 64 small element multipliers.
        assert_eq!(h.small_int_muls, 64);
        assert_eq!(n.small_int_muls, 64);
        // NVFP4's final accumulation is floating-point; HiF4's is not.
        assert!(h.fp_adds == 0 && n.fp_adds == 3);
    }
}
