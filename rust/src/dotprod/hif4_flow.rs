//! The HiF4 64-length dot-product PE flow (Fig 4, left; eq. 3).
//!
//! Datapath stages, all integer until the very last step:
//!
//! 1. **Absorb level-3**: S1P2 (±7 quarter-units) << E1_16 → S2P2 (±14
//!    quarter-units, 5-bit signed) — "multiplier inputs become 5-bit
//!    integers".
//! 2. **64 multiplies**: S2P2×S2P2 → products in 1/16 units, |p| ≤ 196.
//! 3. **Integer tree**: within each level-2 span of 8, sum 8 products
//!    (7 adds); shift each span sum left by `E1_8^A[j] + E1_8^B[j]`
//!    (0..=2); sum the 8 span results (7 adds) → one **S12P4** integer
//!    (17-bit signed, 1/16 units).
//! 4. **Final stage**: one small FP multiplier forms `E6M2^A × E6M2^B`
//!    (3-bit × 3-bit significands, exponents add); one large integer
//!    multiplier applies the S12P4 integer to the product significand.

use super::FlowStats;
use crate::formats::e6m2::E6M2;
use crate::formats::hif4::{HiF4Unit, GROUP, L2_SPAN};

/// Exact bit-width bookkeeping for the flow (used by tests + hwcost).
pub fn stats() -> FlowStats {
    FlowStats {
        small_int_muls: 64,
        small_fp_muls: 1,
        large_int_muls: 1,
        fp_adds: 0,
        // 8 spans × 7 intra-span adds + 7 inter-span adds.
        int_adds: 8 * 7 + 7,
        // S12P4: sign + 12 integer + 4 fraction bits.
        final_int_bits: 17,
    }
}

/// Intermediate integers of the flow, exposed for bit-width assertions.
#[derive(Debug, Clone)]
pub struct HiF4DotTrace {
    /// The 64 S2P2 operand pairs (quarter-units, |x| ≤ 14).
    pub s2p2_a: [i16; GROUP],
    pub s2p2_b: [i16; GROUP],
    /// Span sums after the level-2 shift (1/16 units).
    pub span_sums: [i32; 8],
    /// The single reduced integer (1/16 units) — fits S12P4.
    pub s12p4: i32,
    /// The E6M2×E6M2 scale product.
    pub scale_product: f64,
}

/// Execute the flow bit-exactly. Returns the dot product and the trace.
///
/// NaN scales (the format's only NaN channel) propagate to a NaN result.
pub fn dot_trace(a: &HiF4Unit, b: &HiF4Unit) -> (f64, HiF4DotTrace) {
    let mut t = HiF4DotTrace {
        s2p2_a: [0; GROUP],
        s2p2_b: [0; GROUP],
        span_sums: [0; 8],
        s12p4: 0,
        scale_product: f64::NAN,
    };
    if a.scale.is_nan() || b.scale.is_nan() {
        return (f64::NAN, t);
    }

    // Stage 1: absorb level-3 micro-exponents into the elements.
    for i in 0..GROUP {
        t.s2p2_a[i] = (a.elem(i).signed_q() as i16) << a.l3(i);
        t.s2p2_b[i] = (b.elem(i).signed_q() as i16) << b.l3(i);
        debug_assert!(t.s2p2_a[i].abs() <= 14 && t.s2p2_b[i].abs() <= 14);
    }

    // Stages 2-3: 64 products, integer adder tree, level-2 shifts.
    // BOUND: GROUP-sized spans ≪ IDOT_I32_SAFE_LANES, so the widening
    // i32 span/total accumulators cannot wrap (S2P2 products are ≤ 8 bits
    // each; whole-row reductions go through lanes_idot_exact instead).
    let mut total: i32 = 0;
    for j in 0..GROUP / L2_SPAN {
        let mut span: i32 = 0;
        for k in 0..L2_SPAN {
            let i = j * L2_SPAN + k;
            span += (t.s2p2_a[i] as i32) * (t.s2p2_b[i] as i32);
        }
        let shift = a.l2(j * L2_SPAN) + b.l2(j * L2_SPAN);
        debug_assert!(shift <= 2);
        let shifted = span << shift;
        t.span_sums[j] = shifted;
        total += shifted;
    }
    t.s12p4 = total;
    // S12P4 bound: 64 × 196 × 4 = 50176 < 2^16 in 1/16 units → 17 bits.
    debug_assert!(total.abs() <= 50176);

    // Stage 4: one small FP multiply + one large INT multiply.
    let scale_product = scale_mul_exact(a.scale, b.scale);
    t.scale_product = scale_product;
    // The "large integer multiplier": scale-product significand × S12P4.
    // In f64 this is exact: ≤6-bit significand × 17-bit integer.
    let result = scale_product * (total as f64) / 16.0;
    (result, t)
}

/// The small FP multiplier: E6M2 × E6M2 exactly (3-bit × 3-bit significands
/// never round; exponents add — range [-96, 30] well inside f64).
pub fn scale_mul_exact(a: E6M2, b: E6M2) -> f64 {
    (a.to_f32() as f64) * (b.to_f32() as f64)
}

/// Execute the flow without the trace.
pub fn dot(a: &HiF4Unit, b: &HiF4Unit) -> f64 {
    dot_trace(a, b).0
}

/// Reference: dequantize both units and dot in f64 — the flow must match
/// this *exactly* (property test below).
pub fn dot_dequant_ref(a: &HiF4Unit, b: &HiF4Unit) -> f64 {
    let mut acc = 0f64;
    for i in 0..GROUP {
        acc += (a.decode(i) as f64) * (b.decode(i) as f64);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::hif4::quantize;
    use crate::formats::rounding::RoundMode;
    use crate::tensor::rng::Rng;

    fn random_unit(rng: &mut Rng, sigma: f32) -> HiF4Unit {
        let v: Vec<f32> = (0..GROUP).map(|_| rng.normal() as f32 * sigma).collect();
        quantize(&v, RoundMode::NearestEven)
    }

    #[test]
    fn flow_matches_dequant_reference_exactly() {
        // 200 random unit pairs across 6 decades of scale: the integer flow
        // must equal the dequantized dot bit-for-bit in f64.
        let mut rng = Rng::seed(101);
        for round in 0..200 {
            let sigma = 10f32.powi((round % 6) - 3);
            let a = random_unit(&mut rng, sigma);
            let b = random_unit(&mut rng, sigma);
            let flow = dot(&a, &b);
            let reference = dot_dequant_ref(&a, &b);
            assert_eq!(flow, reference, "round {round}");
        }
    }

    #[test]
    fn s12p4_bound_is_tight_and_respected() {
        // All-max units: every element ±1.75, all micro-exponents set.
        let mut v = [0f32; GROUP];
        for (i, x) in v.iter_mut().enumerate() {
            *x = if i % 2 == 0 { 7.0 } else { -7.0 };
        }
        let a = quantize(&v, RoundMode::NearestEven);
        let (d, t) = dot_trace(&a, &a);
        // Worst case the reduced integer hits exactly ±50176 (here +).
        assert!(t.s12p4.abs() <= 50176);
        assert!(d > 0.0);
    }

    #[test]
    fn zero_units_dot_to_zero() {
        let z = quantize(&[0.0; GROUP], RoundMode::NearestEven);
        assert_eq!(dot(&z, &z), 0.0);
    }

    #[test]
    fn nan_scale_propagates() {
        let mut v = [1.0f32; GROUP];
        v[0] = f32::NAN;
        let a = quantize(&v, RoundMode::NearestEven);
        let b = quantize(&[1.0; GROUP], RoundMode::NearestEven);
        assert!(dot(&a, &b).is_nan());
    }

    #[test]
    fn operand_bit_widths() {
        let mut rng = Rng::seed(102);
        for _ in 0..50 {
            let a = random_unit(&mut rng, 1.0);
            let b = random_unit(&mut rng, 1.0);
            let (_, t) = dot_trace(&a, &b);
            for i in 0..GROUP {
                // S2P2 = 5-bit signed: |x| ≤ 14 quarter-units.
                assert!(t.s2p2_a[i].abs() <= 14);
                assert!(t.s2p2_b[i].abs() <= 14);
            }
        }
    }
}
