//! The NVFP4 64-length dot-product PE flow (Fig 4, right).
//!
//! Four group pairs (4 × 16 = 64) feed the PE. Per group:
//!
//! 1. E2M1 (±6, half-units) → **S3P1** 5-bit signed integers (±12).
//! 2. 16 multiplies → products in 1/4 units, |p| ≤ 144.
//! 3. Integer tree: 15 adds → **S10P2** partial (|sum| ≤ 2304, 13-bit).
//! 4. Per group: one small FP multiplier (E4M3 × E4M3) and one large
//!    integer multiplier → 4 floating-point partials.
//! 5. Final accumulation of the 4 partials **in floating point** (3 adds).
//!
//! Relative to HiF4 this spends 4× the metadata multipliers and an FP
//! accumulation stage — the §III.B area/power argument.

use super::FlowStats;
use crate::formats::nvfp4::{Nvfp4Group, GROUP};

/// Number of NVFP4 group pairs per 64-length PE.
pub const GROUPS_PER_PE: usize = 4;

/// Datapath statistics (see [`FlowStats`]).
pub fn stats() -> FlowStats {
    FlowStats {
        small_int_muls: 64,
        small_fp_muls: GROUPS_PER_PE,
        large_int_muls: GROUPS_PER_PE,
        // Final accumulation from 4 partials: 3 FP adds.
        fp_adds: GROUPS_PER_PE - 1,
        // 4 groups × 15 intra-group adds.
        int_adds: GROUPS_PER_PE * 15,
        // S10P2: sign + 10 integer + 2 fraction bits.
        final_int_bits: 13,
    }
}

/// Intermediate values, exposed for bit-width assertions.
#[derive(Debug, Clone)]
pub struct Nvfp4DotTrace {
    /// Per-group reduced integers (1/4 units) — each fits S10P2.
    pub s10p2: [i32; GROUPS_PER_PE],
    /// Per-group scale products (E4M3 × E4M3, exact).
    pub scale_products: [f64; GROUPS_PER_PE],
    /// The four floating-point partials entering the final FP tree.
    pub partials: [f64; GROUPS_PER_PE],
}

/// Execute the 64-length flow over 4 group pairs, bit-exactly.
pub fn dot64_trace(a: &[Nvfp4Group], b: &[Nvfp4Group]) -> (f64, Nvfp4DotTrace) {
    assert_eq!(a.len(), GROUPS_PER_PE);
    assert_eq!(b.len(), GROUPS_PER_PE);
    let mut t = Nvfp4DotTrace {
        s10p2: [0; GROUPS_PER_PE],
        scale_products: [0.0; GROUPS_PER_PE],
        partials: [0.0; GROUPS_PER_PE],
    };
    for g in 0..GROUPS_PER_PE {
        if a[g].scale.is_nan() || b[g].scale.is_nan() {
            return (f64::NAN, t);
        }
        let mut sum: i32 = 0;
        for i in 0..GROUP {
            let xa = a[g].elem(i).signed_halves() as i32; // S3P1, ±12
            let xb = b[g].elem(i).signed_halves() as i32;
            debug_assert!(xa.abs() <= 12 && xb.abs() <= 12);
            sum += xa * xb;
        }
        debug_assert!(sum.abs() <= 2304, "S10P2 bound");
        t.s10p2[g] = sum;
        // Small FP multiplier: E4M3 × E4M3 is exact in f64 (4b × 4b sig).
        let sp = (a[g].scale.to_f32() as f64) * (b[g].scale.to_f32() as f64);
        t.scale_products[g] = sp;
        // Large integer multiplier: scale significand × S10P2 (exact).
        t.partials[g] = sp * (sum as f64) / 4.0;
    }
    // Final floating-point accumulation (balanced 3-add tree).
    let r = (t.partials[0] + t.partials[1]) + (t.partials[2] + t.partials[3]);
    (r, t)
}

/// Flow without the trace.
pub fn dot64(a: &[Nvfp4Group], b: &[Nvfp4Group]) -> f64 {
    dot64_trace(a, b).0
}

/// One group pair through the fixed-point datapath: S3P1 integer products,
/// 15-add tree, then the group's small-FP × large-INT final stage —
/// exactly one of [`dot64`]'s four partials, usable on its own for tail
/// groups that don't fill a 64-length PE.
///
/// Bit-identical to [`dot64_dequant_ref`] on a single group pair: every
/// f64 partial sum of the dequantized walk is `(sa·sb)·H/4` with `H` a
/// ≤12-bit integer and `sa·sb` a ≤8-bit-significand dyadic, so both
/// computations are exact and equal (pinned by the test below). The one
/// unreachable caveat: a hand-built group with a zero scale but nonzero
/// elements would differ in the *sign* of zero — [`quantize`] can never
/// emit that shape (a zero scale zeroes every element).
///
/// [`quantize`]: crate::formats::nvfp4::quantize
pub fn dot_group(a: &Nvfp4Group, b: &Nvfp4Group) -> f64 {
    if a.scale.is_nan() || b.scale.is_nan() {
        return f64::NAN;
    }
    // BOUND: GROUP lanes ≪ IDOT_I32_SAFE_LANES and the S10P2 partial is
    // debug-asserted below, so the widening i32 accumulator cannot wrap
    // (whole-row reductions go through lanes_idot_exact instead).
    let mut sum: i32 = 0;
    for i in 0..GROUP {
        sum += (a.elem(i).signed_halves() as i32) * (b.elem(i).signed_halves() as i32);
    }
    debug_assert!(sum.abs() <= 2304, "S10P2 bound");
    let sp = (a.scale.to_f32() as f64) * (b.scale.to_f32() as f64);
    sp * (sum as f64) / 4.0
}

/// Reference: dequantized f64 dot product over any number of group pairs
/// (also serves as the tail path of the quantized GEMM).
pub fn dot64_dequant_ref(a: &[Nvfp4Group], b: &[Nvfp4Group]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0f64;
    for g in 0..a.len() {
        for i in 0..GROUP {
            acc += (a[g].decode(i) as f64) * (b[g].decode(i) as f64);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::nvfp4::quantize;
    use crate::formats::rounding::RoundMode;
    use crate::tensor::rng::Rng;

    fn random_groups(rng: &mut Rng, sigma: f32) -> Vec<Nvfp4Group> {
        (0..GROUPS_PER_PE)
            .map(|_| {
                let v: Vec<f32> = (0..GROUP).map(|_| rng.normal() as f32 * sigma).collect();
                quantize(&v, RoundMode::NearestEven)
            })
            .collect()
    }

    #[test]
    fn flow_matches_dequant_reference_exactly() {
        let mut rng = Rng::seed(201);
        for round in 0..200 {
            let sigma = 10f32.powi((round % 5) - 2);
            let a = random_groups(&mut rng, sigma);
            let b = random_groups(&mut rng, sigma);
            assert_eq!(dot64(&a, &b), dot64_dequant_ref(&a, &b), "round {round}");
        }
    }

    #[test]
    fn dot_group_equals_dequant_ref_exactly() {
        // The single-group integer partial must match the dequantized f64
        // walk bit for bit across scale decades (incl. groups whose scale
        // underflows to zero at tiny sigma).
        let mut rng = Rng::seed(203);
        for round in 0..300 {
            let sigma = 10f32.powi((round % 6) - 3);
            let v: Vec<f32> = (0..GROUP).map(|_| rng.normal() as f32 * sigma).collect();
            let w: Vec<f32> = (0..GROUP).map(|_| rng.normal() as f32 * sigma).collect();
            let a = quantize(&v, RoundMode::NearestEven);
            let b = quantize(&w, RoundMode::NearestEven);
            let int_partial = dot_group(&a, &b);
            let reference =
                dot64_dequant_ref(core::slice::from_ref(&a), core::slice::from_ref(&b));
            assert_eq!(int_partial.to_bits(), reference.to_bits(), "round {round}");
        }
    }

    #[test]
    fn dot_group_sums_to_dot64() {
        // Four group partials accumulated through dot64's balanced tree
        // must reproduce dot64 itself.
        let mut rng = Rng::seed(204);
        let a = random_groups(&mut rng, 1.0);
        let b = random_groups(&mut rng, 1.0);
        let p: Vec<f64> = (0..GROUPS_PER_PE).map(|g| dot_group(&a[g], &b[g])).collect();
        let tree = (p[0] + p[1]) + (p[2] + p[3]);
        assert_eq!(tree.to_bits(), dot64(&a, &b).to_bits());
    }

    #[test]
    fn s10p2_bound() {
        let v: Vec<f32> = (0..GROUP).map(|i| if i % 2 == 0 { 6.0 } else { -6.0 }).collect();
        let g = quantize(&v, RoundMode::NearestEven);
        let a = vec![g.clone(), g.clone(), g.clone(), g.clone()];
        let (_, t) = dot64_trace(&a, &a);
        for s in t.s10p2 {
            assert_eq!(s, 2304, "all-max groups hit the S10P2 bound exactly");
        }
    }

    #[test]
    fn exactly_representable_tensor_dots_exactly() {
        // A tensor whose groups have amax = 6 (scale 1.0, exact in E4M3) and
        // whose elements lie on the E2M1 grid is represented exactly, so the
        // flow must return the *true* dot product of the original values.
        let grid = [0.0f32, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
        let mut rng = Rng::seed(202);
        let pick = |rng: &mut Rng| {
            let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            grid[rng.below(8)] * sign
        };
        let mut v: Vec<f32> = (0..64).map(|_| pick(&mut rng)).collect();
        let mut w: Vec<f32> = (0..64).map(|_| pick(&mut rng)).collect();
        for g in 0..4 {
            v[g * 16] = 6.0; // pin each group's amax to 6
            w[g * 16] = -6.0;
        }
        let na: Vec<Nvfp4Group> =
            v.chunks(16).map(|c| quantize(c, RoundMode::NearestEven)).collect();
        let nb: Vec<Nvfp4Group> =
            w.chunks(16).map(|c| quantize(c, RoundMode::NearestEven)).collect();
        let exact: f64 = v.iter().zip(&w).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        assert_eq!(dot64(&na, &nb), exact);
    }
}
