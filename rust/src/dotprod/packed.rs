//! Decode-once packed integer operand planes for the quantized GEMMs.
//!
//! The flow kernels ([`hif4_flow`], [`nvfp4_flow`]) re-extract every 4-bit
//! nibble and micro-exponent *per output element*: a `C = A·Bᵀ` product
//! pays that decode tax O(M·N·K) times even though the operands only hold
//! O(M·K + N·K) quantized values. The packed planes here decode each unit
//! exactly **once**, at pack time, into a layout the inner GEMM loop can
//! consume as a straight `i8 × i8 → i32` dot product over contiguous,
//! cache-line-aligned slices — SWAR/auto-vectorizer friendly — with one
//! floating-point scale fixup per unit.
//!
//! ## Why the results are bit-identical to the flows
//!
//! Per HiF4 unit pair the flow computes (see [`hif4_flow::dot_trace`]):
//!
//! ```text
//! p_i      = (qa_i << l3a_i) · (qb_i << l3b_i)          (64 products)
//! span_j   = (Σ_{i∈span j} p_i) << (l2a_j + l2b_j)      (8 spans of 8)
//! total    = Σ_j span_j                                 (S12P4, 17-bit)
//! result   = (E6M2_a·E6M2_b) · total / 16
//! ```
//!
//! Left shifts distribute over exact integer sums, so absorbing **both**
//! micro-exponent levels into the lanes at pack time —
//! `lane_i = q_i << (l2_i + l3_i)`, magnitude ≤ 7·4 = 28, comfortably an
//! `i8` — yields `Σ_i lane_a_i · lane_b_i == total` exactly: the per-span
//! shift bytes of the flow are *pre-applied* to the lanes, and the whole
//! unit dot collapses to one 64-lane integer dot product with no per-span
//! fixup left. The final scale fixup replays the flow's exact f64 sequence
//! (`(sa·sb) · total / 16`, with each scale stored as its exact `f64`
//! value, `NaN` for the poisoned-unit channel), so every unit dot — and
//! therefore every GEMM cell, which accumulates unit dots in the same
//! ascending-K f64 order — matches the flow bit for bit. NVFP4 lanes are
//! the S3P1 half-unit integers (|x| ≤ 12); its per-group partial is
//! `(sa·sb) · sum / 4` and four partials reduce through the same balanced
//! `(p0+p1)+(p2+p3)` tree as [`nvfp4_flow::dot64`].
//!
//! Packing costs O(M·K + N·K) and is row-parallel over
//! [`parallel_row_bands2`]; once packed, planes can be reused across any
//! number of GEMM calls (the model's real-quantized linears keep weight
//! planes alive across every token). The kernels keep the flow GEMMs'
//! JB×UB cache blocking and their any-thread-count determinism contract.
//!
//! [`hif4_flow`]: super::hif4_flow
//! [`hif4_flow::dot_trace`]: super::hif4_flow::dot_trace

use super::nvfp4_flow;
use super::qgemm::{HiF4Matrix, Nvfp4Matrix, JB, UB};
use crate::formats::hif4::{self, HiF4Unit};
use crate::formats::nvfp4::{self, Nvfp4Group};
use crate::tensor::Matrix;
use crate::util::threadpool::{self, parallel_row_bands, parallel_row_bands2};

/// Flop-equivalents per element of the pack transform (nibble extract,
/// micro-exponent lookup, shift, store) — weights `threads_for` so packing
/// mid-sized operands still fans out.
const PACK_WORK_PER_ELEM: usize = 4;

/// One HiF4 unit's 64 operand lanes, aligned to a cache line so a unit
/// never straddles two lines.
#[derive(Debug, Clone, Copy)]
#[repr(align(64))]
pub struct HiF4Lanes(pub [i8; hif4::GROUP]);

impl HiF4Lanes {
    /// Decode the first `out.len()` lanes back to f32 given the unit's
    /// exact scale. Lanes are S1P2 quarter-units with both micro-exponent
    /// levels absorbed, so `v_i = scale · lane_i / 4` — one multiply per
    /// element, bit-identical to [`HiF4Unit::decode`] (`lane·0.25` is
    /// exact, the scale product rounds once in both formulations; a NaN
    /// scale poisons every element, matching the unit's NaN channel).
    pub fn decode_into(&self, scale: f64, out: &mut [f32]) {
        assert!(
            out.len() <= hif4::GROUP,
            "HiF4 unit decodes at most {} elements; buffer holds {}",
            hif4::GROUP,
            out.len()
        );
        let s = scale as f32;
        for (o, lane) in out.iter_mut().zip(self.0.iter()) {
            *o = s * (*lane as f32 * 0.25);
        }
    }
}

/// Decode one HiF4 unit into its decode-once plane: the 64
/// micro-exponent-absorbed `i8` lanes plus the exact `f64` level-1 scale
/// (`NaN` for a poisoned unit). This is the per-unit transform behind
/// [`PackedHiF4Matrix::pack`], exposed so row-granular consumers (the
/// HiF4 KV cache) can share the exact same encode-once layout.
pub fn hif4_unit_plane(u: &HiF4Unit) -> (HiF4Lanes, f64) {
    let mut lanes = HiF4Lanes([0; hif4::GROUP]);
    let scale = pack_hif4_unit(u, &mut lanes);
    (lanes, scale)
}

/// One NVFP4 group's 16 operand lanes (S3P1 half-units), 16-byte aligned.
#[derive(Debug, Clone, Copy)]
#[repr(align(16))]
pub struct Nvfp4Lanes(pub [i8; nvfp4::GROUP]);

/// Straight 64-lane `i8 × i8 → i32` dot — the entire fixed-point part of
/// one HiF4 unit dot. Integer adds are associative, so the optimizer is
/// free to vectorize/reassociate; the result is exact either way.
#[inline]
fn lanes_dot64(a: &HiF4Lanes, b: &HiF4Lanes) -> i32 {
    let mut acc = 0i32;
    for i in 0..hif4::GROUP {
        acc += (a.0[i] as i32) * (b.0[i] as i32);
    }
    acc
}

/// 16-lane integer dot for one NVFP4 group pair.
#[inline]
fn lanes_dot16(a: &Nvfp4Lanes, b: &Nvfp4Lanes) -> i32 {
    let mut acc = 0i32;
    for i in 0..nvfp4::GROUP {
        acc += (a.0[i] as i32) * (b.0[i] as i32);
    }
    acc
}

/// Decode one HiF4 unit into its lanes; returns the unit's exact scale as
/// f64 (`NaN` when the unit is NaN-poisoned, the format's only NaN
/// channel).
#[inline]
fn pack_hif4_unit(u: &HiF4Unit, lanes: &mut HiF4Lanes) -> f64 {
    for i in 0..hif4::GROUP {
        // Absorb level 2 *and* level 3: q ≤ 7 shifted by ≤ 2 stays ≤ 28.
        lanes.0[i] = u.elem(i).signed_q() << (u.l2(i) + u.l3(i));
    }
    if u.scale.is_nan() {
        f64::NAN
    } else {
        u.scale.to_f32() as f64
    }
}

/// Decode one NVFP4 group into S3P1 half-unit lanes; returns the exact
/// f64 scale (`NaN` channel included).
#[inline]
fn pack_nvfp4_group(g: &Nvfp4Group, lanes: &mut Nvfp4Lanes) -> f64 {
    for i in 0..nvfp4::GROUP {
        lanes.0[i] = g.elem(i).signed_halves();
    }
    if g.scale.is_nan() {
        f64::NAN
    } else {
        g.scale.to_f32() as f64
    }
}

/// A [`HiF4Matrix`] re-laid-out as decode-once integer operand planes:
/// per unit, 64 contiguous micro-exponent-absorbed `i8` lanes plus the
/// exact `f64` level-1 scale.
#[derive(Debug, Clone)]
pub struct PackedHiF4Matrix {
    pub rows: usize,
    pub cols: usize,
    pub units_per_row: usize,
    lanes: Vec<HiF4Lanes>,
    scales: Vec<f64>,
}

impl PackedHiF4Matrix {
    /// Pack with the process-default thread count (rows pack
    /// independently, so the result is identical for any count).
    pub fn pack(q: &HiF4Matrix) -> PackedHiF4Matrix {
        Self::pack_threads(q, threadpool::threads_for(q.rows * q.cols * PACK_WORK_PER_ELEM))
    }

    /// [`PackedHiF4Matrix::pack`] with an explicit thread count.
    pub fn pack_threads(q: &HiF4Matrix, threads: usize) -> PackedHiF4Matrix {
        q.assert_geometry();
        let upr = q.units_per_row;
        let n = q.rows * upr;
        let mut lanes = vec![HiF4Lanes([0; hif4::GROUP]); n];
        let mut scales = vec![0f64; n];
        if n > 0 {
            parallel_row_bands2(&mut lanes, upr, &mut scales, upr, threads, |first_row, lb, sb| {
                for (i, (lrow, srow)) in lb.chunks_mut(upr).zip(sb.chunks_mut(upr)).enumerate() {
                    let units = q.row_units(first_row + i);
                    for ((l, s), u) in lrow.iter_mut().zip(srow.iter_mut()).zip(units) {
                        *s = pack_hif4_unit(u, l);
                    }
                }
            });
        }
        PackedHiF4Matrix { rows: q.rows, cols: q.cols, units_per_row: upr, lanes, scales }
    }

    /// Quantize + pack in one step (convenience for activation operands).
    pub fn quantize(m: &Matrix, mode: crate::formats::rounding::RoundMode) -> PackedHiF4Matrix {
        Self::pack(&HiF4Matrix::quantize(m, mode))
    }

    /// Lane plane of row `r` (one entry per K unit).
    #[inline]
    pub fn row_lanes(&self, r: usize) -> &[HiF4Lanes] {
        &self.lanes[r * self.units_per_row..(r + 1) * self.units_per_row]
    }

    /// Scale plane of row `r`.
    #[inline]
    pub fn row_scales(&self, r: usize) -> &[f64] {
        &self.scales[r * self.units_per_row..(r + 1) * self.units_per_row]
    }

    /// One unit dot against another packed matrix — bit-identical to
    /// [`super::hif4_flow::dot`] on the corresponding units (pinned by
    /// `tests/packed_parity.rs`).
    pub fn dot_unit(
        &self,
        r: usize,
        u: usize,
        other: &PackedHiF4Matrix,
        ro: usize,
        uo: usize,
    ) -> f64 {
        let total = lanes_dot64(&self.row_lanes(r)[u], &other.row_lanes(ro)[uo]);
        let sp = self.row_scales(r)[u] * other.row_scales(ro)[uo];
        sp * (total as f64) / 16.0
    }
}

/// An [`Nvfp4Matrix`] as decode-once planes: 16 S3P1 `i8` lanes + exact
/// `f64` scale per group.
#[derive(Debug, Clone)]
pub struct PackedNvfp4Matrix {
    pub rows: usize,
    pub cols: usize,
    pub groups_per_row: usize,
    lanes: Vec<Nvfp4Lanes>,
    scales: Vec<f64>,
}

impl PackedNvfp4Matrix {
    /// Pack with the process-default thread count.
    pub fn pack(q: &Nvfp4Matrix) -> PackedNvfp4Matrix {
        Self::pack_threads(q, threadpool::threads_for(q.rows * q.cols * PACK_WORK_PER_ELEM))
    }

    /// [`PackedNvfp4Matrix::pack`] with an explicit thread count.
    pub fn pack_threads(q: &Nvfp4Matrix, threads: usize) -> PackedNvfp4Matrix {
        q.assert_geometry();
        let gpr = q.groups_per_row;
        let n = q.rows * gpr;
        let mut lanes = vec![Nvfp4Lanes([0; nvfp4::GROUP]); n];
        let mut scales = vec![0f64; n];
        if n > 0 {
            parallel_row_bands2(&mut lanes, gpr, &mut scales, gpr, threads, |first_row, lb, sb| {
                for (i, (lrow, srow)) in lb.chunks_mut(gpr).zip(sb.chunks_mut(gpr)).enumerate() {
                    let groups = q.row_groups(first_row + i);
                    for ((l, s), g) in lrow.iter_mut().zip(srow.iter_mut()).zip(groups) {
                        *s = pack_nvfp4_group(g, l);
                    }
                }
            });
        }
        PackedNvfp4Matrix { rows: q.rows, cols: q.cols, groups_per_row: gpr, lanes, scales }
    }

    /// Quantize + pack in one step.
    pub fn quantize(m: &Matrix, mode: crate::formats::rounding::RoundMode) -> PackedNvfp4Matrix {
        Self::pack(&Nvfp4Matrix::quantize(m, mode))
    }

    #[inline]
    pub fn row_lanes(&self, r: usize) -> &[Nvfp4Lanes] {
        &self.lanes[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }

    #[inline]
    pub fn row_scales(&self, r: usize) -> &[f64] {
        &self.scales[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }

    /// One group's integer partial against another packed matrix —
    /// bit-identical to [`nvfp4_flow::dot_group`] on the corresponding
    /// groups.
    pub fn dot_group(
        &self,
        r: usize,
        g: usize,
        other: &PackedNvfp4Matrix,
        ro: usize,
        go: usize,
    ) -> f64 {
        let sum = lanes_dot16(&self.row_lanes(r)[g], &other.row_lanes(ro)[go]);
        let sp = self.row_scales(r)[g] * other.row_scales(ro)[go];
        sp * (sum as f64) / 4.0
    }
}

/// `C = A · Bᵀ` over packed HiF4 planes with the process-default thread
/// count. Bit-identical to [`super::qgemm::hif4_gemm_bt_flow`] on the
/// matrices the planes were packed from.
pub fn hif4_gemm_bt_packed(a: &PackedHiF4Matrix, b_t: &PackedHiF4Matrix) -> Matrix {
    let work = a.rows * b_t.rows * a.cols;
    hif4_gemm_bt_packed_threads(a, b_t, threadpool::threads_for(work))
}

/// [`hif4_gemm_bt_packed`] with an explicit thread count — bit-identical
/// for every value (each output element accumulates its unit dots in
/// ascending K order on one thread, exactly like the flow kernel).
pub fn hif4_gemm_bt_packed_threads(
    a: &PackedHiF4Matrix,
    b_t: &PackedHiF4Matrix,
    threads: usize,
) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    let (n, upr) = (b_t.rows, a.units_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [0f64; JB];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            for i in 0..rows {
                let al = a.row_lanes(first_row + i);
                let asc = a.row_scales(first_row + i);
                accs[..jb].fill(0.0);
                // Same JB × UB panel blocking as the flow kernel; per
                // (i, j) the accumulation stays ascending-u.
                for u0 in (0..upr).step_by(UB) {
                    let u1 = (u0 + UB).min(upr);
                    let al_blk = &al[u0..u1];
                    let asc_blk = &asc[u0..u1];
                    for (jj, acc) in accs[..jb].iter_mut().enumerate() {
                        let bl_blk = &b_t.row_lanes(j0 + jj)[u0..u1];
                        let bsc_blk = &b_t.row_scales(j0 + jj)[u0..u1];
                        for u in 0..al_blk.len() {
                            let total = lanes_dot64(&al_blk[u], &bl_blk[u]);
                            // The flow's final stage, op for op:
                            // (sa·sb) · total / 16.
                            *acc += (asc_blk[u] * bsc_blk[u]) * (total as f64) / 16.0;
                        }
                    }
                }
                let crow = &mut band[i * n..(i + 1) * n];
                for (jj, acc) in accs[..jb].iter().enumerate() {
                    crow[j0 + jj] = *acc as f32;
                }
            }
        }
    });
    c
}

/// `C = A · Bᵀ` over packed NVFP4 planes (process-default threads).
pub fn nvfp4_gemm_bt_packed(a: &PackedNvfp4Matrix, b_t: &PackedNvfp4Matrix) -> Matrix {
    let work = a.rows * b_t.rows * a.cols;
    nvfp4_gemm_bt_packed_threads(a, b_t, threadpool::threads_for(work))
}

/// [`nvfp4_gemm_bt_packed`] with an explicit thread count — bit-identical
/// to the flow kernel: full PEs reduce four group partials through the
/// same balanced `(p0+p1)+(p2+p3)` tree as [`nvfp4_flow::dot64`], tail
/// groups add their single integer partial directly (the
/// [`nvfp4_flow::dot_group`] path).
pub fn nvfp4_gemm_bt_packed_threads(
    a: &PackedNvfp4Matrix,
    b_t: &PackedNvfp4Matrix,
    threads: usize,
) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    const PE: usize = nvfp4_flow::GROUPS_PER_PE;
    const _: () = assert!(UB % PE == 0);
    let (n, gpr) = (b_t.rows, a.groups_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    // One group's partial: the flow's per-group final stage, op for op.
    let partial = |al: &Nvfp4Lanes, asv: f64, bl: &Nvfp4Lanes, bsv: f64| -> f64 {
        (asv * bsv) * (lanes_dot16(al, bl) as f64) / 4.0
    };
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [0f64; JB];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            for i in 0..rows {
                let al = a.row_lanes(first_row + i);
                let asc = a.row_scales(first_row + i);
                accs[..jb].fill(0.0);
                for u0 in (0..gpr).step_by(UB) {
                    let u1 = (u0 + UB).min(gpr);
                    for (jj, acc) in accs[..jb].iter_mut().enumerate() {
                        let bl = b_t.row_lanes(j0 + jj);
                        let bsc = b_t.row_scales(j0 + jj);
                        let mut g = u0;
                        while g + PE <= u1 {
                            let p0 = partial(&al[g], asc[g], &bl[g], bsc[g]);
                            let p1 = partial(&al[g + 1], asc[g + 1], &bl[g + 1], bsc[g + 1]);
                            let p2 = partial(&al[g + 2], asc[g + 2], &bl[g + 2], bsc[g + 2]);
                            let p3 = partial(&al[g + 3], asc[g + 3], &bl[g + 3], bsc[g + 3]);
                            // dot64's balanced accumulation tree.
                            *acc += (p0 + p1) + (p2 + p3);
                            g += PE;
                        }
                        while g < u1 {
                            *acc += partial(&al[g], asc[g], &bl[g], bsc[g]);
                            g += 1;
                        }
                    }
                }
                let crow = &mut band[i * n..(i + 1) * n];
                for (jj, acc) in accs[..jb].iter().enumerate() {
                    crow[j0 + jj] = *acc as f32;
                }
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dotprod::hif4_flow;
    use crate::formats::rounding::RoundMode;
    use crate::tensor::rng::Rng;

    const MODE: RoundMode = RoundMode::NearestEven;

    #[test]
    fn lane_magnitudes_stay_in_i8() {
        // Worst case: every element ±7 with both micro-exponents set.
        let mut v = [0f32; hif4::GROUP];
        for (i, x) in v.iter_mut().enumerate() {
            *x = if i % 2 == 0 { 7.0 } else { -7.0 };
        }
        let q = HiF4Matrix::quantize(&Matrix::from_vec(1, hif4::GROUP, v.to_vec()), MODE);
        let p = PackedHiF4Matrix::pack(&q);
        for lane in &p.row_lanes(0)[0].0 {
            assert!(lane.abs() <= 28, "lane {lane} exceeds the 7·4 bound");
        }
    }

    #[test]
    fn packed_unit_dot_matches_flow() {
        let mut rng = Rng::seed(501);
        for round in 0..60 {
            let sigma = 10f32.powi((round % 6) - 3);
            let a = Matrix::randn(1, hif4::GROUP, sigma, &mut rng);
            let b = Matrix::randn(1, hif4::GROUP, sigma, &mut rng);
            let qa = HiF4Matrix::quantize(&a, MODE);
            let qb = HiF4Matrix::quantize(&b, MODE);
            let pa = PackedHiF4Matrix::pack(&qa);
            let pb = PackedHiF4Matrix::pack(&qb);
            let flow = hif4_flow::dot(&qa.row_units(0)[0], &qb.row_units(0)[0]);
            assert_eq!(pa.dot_unit(0, 0, &pb, 0, 0).to_bits(), flow.to_bits(), "round {round}");
        }
    }

    #[test]
    fn packed_gemm_matches_flow_gemm_bitwise() {
        let mut rng = Rng::seed(502);
        for (m, k, n) in [(5, 130, 7), (3, 64, 4), (2, 40, 3)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            let qa = HiF4Matrix::quantize(&a, MODE);
            let qb = HiF4Matrix::quantize(&b, MODE);
            let flow = super::super::qgemm::hif4_gemm_bt_flow_threads(&qa, &qb, 1);
            let packed = hif4_gemm_bt_packed_threads(
                &PackedHiF4Matrix::pack(&qa),
                &PackedHiF4Matrix::pack(&qb),
                1,
            );
            assert_eq!(
                flow.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                packed.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn nvfp4_packed_gemm_matches_flow_gemm_bitwise() {
        let mut rng = Rng::seed(503);
        // 72 and 40 cols exercise the tail-group (non-multiple-of-PE) path.
        for (m, k, n) in [(4, 72, 6), (3, 40, 5), (2, 128, 3)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            let qa = Nvfp4Matrix::quantize(&a, MODE);
            let qb = Nvfp4Matrix::quantize(&b, MODE);
            let flow = super::super::qgemm::nvfp4_gemm_bt_flow_threads(&qa, &qb, 1);
            let packed = nvfp4_gemm_bt_packed_threads(
                &PackedNvfp4Matrix::pack(&qa),
                &PackedNvfp4Matrix::pack(&qb),
                1,
            );
            assert_eq!(
                flow.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                packed.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn lane_decode_matches_unit_decode_bitwise() {
        let mut rng = Rng::seed(505);
        for round in 0..40 {
            let sigma = 10f32.powi((round % 8) - 4);
            let v: Vec<f32> = (0..hif4::GROUP).map(|_| rng.normal() as f32 * sigma).collect();
            let unit = hif4::quantize(&v, MODE);
            let (lanes, scale) = hif4_unit_plane(&unit);
            let mut decoded = [0f32; hif4::GROUP];
            lanes.decode_into(scale, &mut decoded);
            for (i, d) in decoded.iter().enumerate() {
                assert_eq!(
                    d.to_bits(),
                    unit.decode(i).to_bits(),
                    "round {round} elem {i}: lane decode diverged from unit decode"
                );
            }
        }
        // NaN channel: a poisoned unit poisons every decoded lane.
        let mut v = vec![1.0f32; hif4::GROUP];
        v[3] = f32::NAN;
        let (lanes, scale) = hif4_unit_plane(&hif4::quantize(&v, MODE));
        let mut decoded = [0f32; hif4::GROUP];
        lanes.decode_into(scale, &mut decoded);
        assert!(decoded.iter().all(|x| x.is_nan()));
    }

    #[test]
    #[should_panic(expected = "HiF4Matrix geometry")]
    fn pack_rejects_inconsistent_geometry() {
        let mut rng = Rng::seed(506);
        let mut q = HiF4Matrix::quantize(&Matrix::randn(2, 130, 1.0, &mut rng), MODE);
        q.units_per_row = 1; // lies about the padded tail unit
        let _ = PackedHiF4Matrix::pack_threads(&q, 1);
    }

    #[test]
    #[should_panic(expected = "Nvfp4Matrix geometry")]
    fn nvfp4_pack_rejects_inconsistent_geometry() {
        let mut rng = Rng::seed(507);
        let mut q = Nvfp4Matrix::quantize(&Matrix::randn(2, 40, 1.0, &mut rng), MODE);
        q.groups.pop(); // drops one tail group
        let _ = PackedNvfp4Matrix::pack_threads(&q, 1);
    }

    #[test]
    fn pack_is_thread_count_invariant() {
        let mut rng = Rng::seed(504);
        let q = HiF4Matrix::quantize(&Matrix::randn(9, 200, 1.0, &mut rng), MODE);
        let serial = PackedHiF4Matrix::pack_threads(&q, 1);
        for t in [2, 3, 5] {
            let par = PackedHiF4Matrix::pack_threads(&q, t);
            assert_eq!(serial.scales, par.scales, "threads={t}");
            for r in 0..q.rows {
                for u in 0..q.units_per_row {
                    assert_eq!(serial.row_lanes(r)[u].0, par.row_lanes(r)[u].0);
                }
            }
        }
    }
}
