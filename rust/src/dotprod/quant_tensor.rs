//! The unified quantized-tensor API: **one** format-generic
//! quantize/pack/GEMM surface over every block format the paper evaluates.
//!
//! Three layers, bottom to top:
//!
//! * [`BlockFormat`] — the per-format codec trait: group size, PE
//!   structure, the integer-lane transform, and the bit-exact flow
//!   partial. Implemented by five zero-sized codecs ([`HiF4Fmt`],
//!   [`Nvfp4Fmt`], [`Mxfp4Fmt`], [`Mx4Fmt`], [`BfpFmt`]).
//! * [`QuantMat<F>`] / [`PackedQuantMat<F>`] — the single generic matrix
//!   implementation (group storage, decode-once integer operand planes)
//!   plus the generic flow/packed GEMM kernels. Monomorphized per format,
//!   so the inner loops stay as tight as the old hand-written per-format
//!   kernels.
//! * [`QuantizedMatrix`] / [`PackedQuantizedMatrix`] — the
//!   enum-dispatched surface keyed by [`QuantKind`] that every consumer
//!   (model linears, KV cache, serving, CLI, benches) programs against:
//!   `quantize`, `dequantize`, `pack`, `qgemm_bt`, `wire_bytes`,
//!   `assert_geometry`.
//!
//! ## Why the packed planes are bit-identical to the flows
//!
//! Every format here is **group-scaled and integer-exact**: a group
//! decodes to `scale · lane_i / LANE_UNIT` where `lane_i` is a small
//! signed integer (micro-exponents, where the format has them, are
//! absorbed into the lanes at pack time — left shifts distribute over
//! exact integer sums, the PR 2 absorption trick). One group-pair partial
//! is therefore
//!
//! ```text
//! partial = (scale_a · scale_b) · (Σ lane_a_i · lane_b_i) / LANE_UNIT²
//! ```
//!
//! computed identically by the element-wise flow (re-extracting lanes per
//! output element) and by the decode-once planes — and, because every
//! factor is a small dyadic rational, identically equal to the
//! dequantized-f64 reference walk. Per format:
//!
//! | codec       | lanes                         | |lane| | partial denom |
//! |-------------|-------------------------------|--------|---------------|
//! | [`HiF4Fmt`] | S1P2 quarters `<< (l2 + l3)`  | ≤ 28   | 16            |
//! | [`Nvfp4Fmt`]| E2M1 halves (S3P1)            | ≤ 12   | 4             |
//! | [`Mxfp4Fmt`]| E2M1 halves (S3P1)            | ≤ 12   | 4             |
//! | [`Mx4Fmt`]  | S1P1 halves `<< (1 − micro)`  | ≤ 6    | 16            |
//! | [`BfpFmt`]  | S1P2 quarters                 | ≤ 7    | 16            |
//!
//! GEMM accumulation replays the Fig-4 PE structure: HiF4 fills a
//! 64-length PE with one group (partials accumulate in ascending K
//! order); NVFP4 reduces [`BlockFormat::GROUPS_PER_PE`] = 4 partials
//! through the balanced `(p0+p1)+(p2+p3)` tree of
//! [`super::nvfp4_flow::dot64`], tail groups staying on the single-group
//! fixed-point path. MXFP4/MX4/BFP have no published PE flow; they use
//! the direct per-group accumulation (`GROUPS_PER_PE = 1`). Every output
//! element sums its partials on one thread in ascending K order, so
//! results are **bit-identical for any thread count and every kernel
//! backend** (pinned by `tests/packed_parity.rs` and
//! `tests/parallel_parity.rs`).
//!
//! ## The SIMD-tiled backend
//!
//! The packed planes have two inner-kernel schedules: the straight
//! scalar dot ([`super::Kernel::Packed`]) and a register-tiled
//! microkernel ([`super::Kernel::Simd`], the default) that processes
//! [`MR`]×[`NR`] output elements per pass — A-row lanes are loaded once
//! per group and reused across [`NR`] B rows, B-row lanes across [`MR`]
//! A rows, with [`MR`]·[`NR`] independent accumulator chains. The lane
//! ISA is picked once at startup by [`super::simd_isa`]: explicit AVX2
//! intrinsics on `x86_64` CPUs that report the feature (16-lane
//! `i8→i16` widening + `vpmaddwd` — exact for any `i8` input, no
//! saturating instruction anywhere), a portable unrolled-scalar
//! microkernel otherwise. Because a group's integer dot is exact under
//! any association and the surrounding `f64` ops replay the scalar
//! kernel's per-element sequence, the tiled backend is bit-identical
//! to the scalar packed kernel and the flow reference on every format.

use super::{hif4_flow, nvfp4_flow, Kernel, SimdIsa};
use crate::formats::bfp::{self, BfpGroup};
use crate::formats::hif4::{self, HiF4Unit};
use crate::formats::mx4::{self, Mx4Group};
use crate::formats::mxfp4::{self, Mxfp4Group};
use crate::formats::nvfp4::{self, Nvfp4Group};
use crate::formats::rounding::RoundMode;
use crate::formats::QuantKind;
use crate::tensor::Matrix;
use crate::util::threadpool::{self, parallel_row_bands, parallel_row_bands2};
use std::marker::PhantomData;

/// B-rows per cache block of the quantized GEMM kernels.
pub(crate) const JB: usize = 16;
/// K-groups per cache block (a multiple of every format's
/// [`BlockFormat::GROUPS_PER_PE`], so PE boundaries never straddle a
/// block edge).
pub(crate) const UB: usize = 16;

/// Flop-equivalents per element of the pack transform (lane extract,
/// micro-exponent shift, store) — weights `threads_for` so packing
/// mid-sized operands still fans out.
const PACK_WORK_PER_ELEM: usize = 4;

// ---------------------------------------------------------------------------
// The per-format codec trait + five codecs
// ---------------------------------------------------------------------------

/// Per-format codec behind the unified quantized-tensor API: everything
/// the generic matrix/GEMM layer needs to know about one block format.
///
/// Invariants every codec upholds (asserted by the parity suites):
///
/// * `decode(group) == scale · lane_i / LANE_UNIT` element-wise, with the
///   exact `f64` scale returned by [`BlockFormat::group_plane`] (`NaN`
///   for a NaN-poisoned group — the only NaN channel any format has);
/// * [`BlockFormat::dot_flow`] equals the packed-plane partial
///   `(scale_a·scale_b) · Σ lane_a·lane_b / LANE_UNIT²` bit for bit;
/// * lanes fit `i8`.
pub trait BlockFormat: Send + Sync + 'static {
    /// The packed group type from [`crate::formats`].
    type Group: Clone + Send + Sync;
    /// The enum key this codec implements.
    const KIND: QuantKind;
    /// Elements per group.
    const GROUP: usize;
    /// Group partials reduced per 64-length PE through a balanced FP tree
    /// (1 = direct ascending accumulation).
    const GROUPS_PER_PE: usize;
    /// Integer-lane unit: `value = scale · lane / LANE_UNIT` (2 = lanes
    /// are halves, 4 = quarters). The group-pair partial divides by
    /// `LANE_UNIT²`.
    const LANE_UNIT: f64;

    /// Quantize exactly `GROUP` values into a packed group.
    fn quantize_group(v: &[f32], mode: RoundMode) -> Self::Group;
    /// Decode the whole group into `out[..GROUP]` (the format's own
    /// decode, shared with the simulated-quantization path).
    fn decode_group(g: &Self::Group, out: &mut [f32]);
    /// Fill the group's `GROUP` integer lanes (micro-exponents absorbed);
    /// return the exact `f64` scale (`NaN` channel included).
    fn group_plane(g: &Self::Group, lanes: &mut [i8]) -> f64;
    /// The reference flow partial for one group pair: re-extracts lanes
    /// per call, bit-identical to the packed partial.
    fn dot_flow(a: &Self::Group, b: &Self::Group) -> f64;
}

/// HiF4 codec: 64-element units, three-level scaling, the paper's format.
#[derive(Debug, Clone, Copy)]
pub struct HiF4Fmt;

impl BlockFormat for HiF4Fmt {
    type Group = HiF4Unit;
    const KIND: QuantKind = QuantKind::HiF4;
    const GROUP: usize = hif4::GROUP;
    const GROUPS_PER_PE: usize = 1;
    const LANE_UNIT: f64 = 4.0;

    fn quantize_group(v: &[f32], mode: RoundMode) -> HiF4Unit {
        hif4::quantize(v, mode)
    }

    fn decode_group(g: &HiF4Unit, out: &mut [f32]) {
        g.decode_all(out);
    }

    fn group_plane(g: &HiF4Unit, lanes: &mut [i8]) -> f64 {
        for (i, lane) in lanes.iter_mut().enumerate().take(Self::GROUP) {
            // Absorb level 2 *and* level 3: q ≤ 7 shifted by ≤ 2 stays ≤ 28.
            *lane = g.elem(i).signed_q() << (g.l2(i) + g.l3(i));
        }
        if g.scale.is_nan() {
            f64::NAN
        } else {
            g.scale.to_f32() as f64
        }
    }

    fn dot_flow(a: &HiF4Unit, b: &HiF4Unit) -> f64 {
        hif4_flow::dot(a, b)
    }
}

/// NVFP4 codec: 16-element groups, E4M3 scale, four groups per PE.
#[derive(Debug, Clone, Copy)]
pub struct Nvfp4Fmt;

impl BlockFormat for Nvfp4Fmt {
    type Group = Nvfp4Group;
    const KIND: QuantKind = QuantKind::Nvfp4;
    const GROUP: usize = nvfp4::GROUP;
    const GROUPS_PER_PE: usize = nvfp4_flow::GROUPS_PER_PE;
    const LANE_UNIT: f64 = 2.0;

    fn quantize_group(v: &[f32], mode: RoundMode) -> Nvfp4Group {
        nvfp4::quantize(v, mode)
    }

    fn decode_group(g: &Nvfp4Group, out: &mut [f32]) {
        g.decode_all(out);
    }

    fn group_plane(g: &Nvfp4Group, lanes: &mut [i8]) -> f64 {
        for (i, lane) in lanes.iter_mut().enumerate().take(Self::GROUP) {
            *lane = g.elem(i).signed_halves();
        }
        if g.scale.is_nan() {
            f64::NAN
        } else {
            g.scale.to_f32() as f64
        }
    }

    fn dot_flow(a: &Nvfp4Group, b: &Nvfp4Group) -> f64 {
        nvfp4_flow::dot_group(a, b)
    }
}

/// MXFP4 codec: 32-element groups, power-of-two E8M0 scale, E2M1 elements.
#[derive(Debug, Clone, Copy)]
pub struct Mxfp4Fmt;

impl BlockFormat for Mxfp4Fmt {
    type Group = Mxfp4Group;
    const KIND: QuantKind = QuantKind::Mxfp4;
    const GROUP: usize = mxfp4::GROUP;
    const GROUPS_PER_PE: usize = 1;
    const LANE_UNIT: f64 = 2.0;

    fn quantize_group(v: &[f32], mode: RoundMode) -> Mxfp4Group {
        mxfp4::quantize(v, mode)
    }

    fn decode_group(g: &Mxfp4Group, out: &mut [f32]) {
        g.decode_all(out);
    }

    fn group_plane(g: &Mxfp4Group, lanes: &mut [i8]) -> f64 {
        for (i, lane) in lanes.iter_mut().enumerate().take(Self::GROUP) {
            *lane = g.elem(i).signed_halves();
        }
        if g.scale.is_nan() {
            f64::NAN
        } else {
            g.scale.to_f32() as f64
        }
    }

    fn dot_flow(a: &Mxfp4Group, b: &Mxfp4Group) -> f64 {
        if a.scale.is_nan() || b.scale.is_nan() {
            return f64::NAN;
        }
        // BOUND: GROUP lanes ≪ IDOT_I32_SAFE_LANES, so the widening i32
        // accumulator cannot wrap (longer spans use lanes_idot_exact).
        let mut sum: i32 = 0;
        for i in 0..Self::GROUP {
            sum += (a.elem(i).signed_halves() as i32) * (b.elem(i).signed_halves() as i32);
        }
        let sp = (a.scale.to_f32() as f64) * (b.scale.to_f32() as f64);
        sp * (sum as f64) / 4.0
    }
}

/// MX4 codec: 16-element groups, shared E8M0 + per-pair 1-bit
/// micro-exponents absorbed into the lanes (S1P1 halves `<< (1 − micro)`).
#[derive(Debug, Clone, Copy)]
pub struct Mx4Fmt;

impl Mx4Fmt {
    /// Micro-exponent-absorbed lane in quarter-units: a set micro bit
    /// halves the sub-group's scale, so `value = scale · lane / 4` with
    /// `lane = halves << (1 − micro)` (magnitude ≤ 3·2 = 6).
    #[inline]
    fn lane(g: &Mx4Group, i: usize) -> i8 {
        g.signed_h(i) << (1 - g.micro_down(i))
    }
}

impl BlockFormat for Mx4Fmt {
    type Group = Mx4Group;
    const KIND: QuantKind = QuantKind::Mx4;
    const GROUP: usize = mx4::GROUP;
    const GROUPS_PER_PE: usize = 1;
    const LANE_UNIT: f64 = 4.0;

    fn quantize_group(v: &[f32], mode: RoundMode) -> Mx4Group {
        mx4::quantize(v, mode)
    }

    fn decode_group(g: &Mx4Group, out: &mut [f32]) {
        g.decode_all(out);
    }

    fn group_plane(g: &Mx4Group, lanes: &mut [i8]) -> f64 {
        for (i, lane) in lanes.iter_mut().enumerate().take(Self::GROUP) {
            *lane = Self::lane(g, i);
        }
        if g.scale.is_nan() {
            f64::NAN
        } else {
            g.scale.to_f32() as f64
        }
    }

    fn dot_flow(a: &Mx4Group, b: &Mx4Group) -> f64 {
        if a.scale.is_nan() || b.scale.is_nan() {
            return f64::NAN;
        }
        // BOUND: GROUP lanes ≪ IDOT_I32_SAFE_LANES, so the widening i32
        // accumulator cannot wrap (longer spans use lanes_idot_exact).
        let mut sum: i32 = 0;
        for i in 0..Self::GROUP {
            sum += (Self::lane(a, i) as i32) * (Self::lane(b, i) as i32);
        }
        let sp = (a.scale.to_f32() as f64) * (b.scale.to_f32() as f64);
        sp * (sum as f64) / 16.0
    }
}

/// Vanilla-BFP codec: 16-element groups, one shared E8M0, S1P2 elements.
#[derive(Debug, Clone, Copy)]
pub struct BfpFmt;

impl BlockFormat for BfpFmt {
    type Group = BfpGroup;
    const KIND: QuantKind = QuantKind::Bfp;
    const GROUP: usize = bfp::GROUP;
    const GROUPS_PER_PE: usize = 1;
    const LANE_UNIT: f64 = 4.0;

    fn quantize_group(v: &[f32], mode: RoundMode) -> BfpGroup {
        bfp::quantize(v, mode)
    }

    fn decode_group(g: &BfpGroup, out: &mut [f32]) {
        g.decode_all(out);
    }

    fn group_plane(g: &BfpGroup, lanes: &mut [i8]) -> f64 {
        for (i, lane) in lanes.iter_mut().enumerate().take(Self::GROUP) {
            *lane = g.elem(i).signed_q();
        }
        if g.scale.is_nan() {
            f64::NAN
        } else {
            g.scale.to_f32() as f64
        }
    }

    fn dot_flow(a: &BfpGroup, b: &BfpGroup) -> f64 {
        if a.scale.is_nan() || b.scale.is_nan() {
            return f64::NAN;
        }
        // BOUND: GROUP lanes ≪ IDOT_I32_SAFE_LANES, so the widening i32
        // accumulator cannot wrap (longer spans use lanes_idot_exact).
        let mut sum: i32 = 0;
        for i in 0..Self::GROUP {
            sum += (a.elem(i).signed_q() as i32) * (b.elem(i).signed_q() as i32);
        }
        let sp = (a.scale.to_f32() as f64) * (b.scale.to_f32() as f64);
        sp * (sum as f64) / 16.0
    }
}

// ---------------------------------------------------------------------------
// The generic matrix + packed planes
// ---------------------------------------------------------------------------

/// A matrix quantized into `F` groups along its rows (row-major; each row
/// padded to a multiple of [`BlockFormat::GROUP`]). The single generic
/// implementation behind every [`QuantizedMatrix`] variant.
#[derive(Debug, Clone)]
pub struct QuantMat<F: BlockFormat> {
    pub rows: usize,
    pub cols: usize,
    pub groups_per_row: usize,
    pub groups: Vec<F::Group>,
}

impl<F: BlockFormat> QuantMat<F> {
    /// Quantize a row-major matrix along its rows (row-parallel with the
    /// process-default thread count; rows quantize independently, so the
    /// result is identical for any count).
    pub fn quantize(m: &Matrix, mode: RoundMode) -> QuantMat<F> {
        let work = m.rows * m.cols * threadpool::QUANT_WORK_PER_ELEM;
        Self::quantize_threads(m, mode, threadpool::threads_for(work))
    }

    /// [`QuantMat::quantize`] with an explicit thread count.
    pub fn quantize_threads(m: &Matrix, mode: RoundMode, threads: usize) -> QuantMat<F> {
        let gpr = m.cols.div_ceil(F::GROUP);
        if m.rows == 0 || gpr == 0 {
            return QuantMat { rows: m.rows, cols: m.cols, groups_per_row: gpr, groups: Vec::new() };
        }
        let zero_buf = vec![0f32; F::GROUP];
        let zero = F::quantize_group(&zero_buf, mode);
        let mut groups = vec![zero; m.rows * gpr];
        parallel_row_bands(&mut groups, gpr, threads, |first_row, band| {
            let mut buf = vec![0f32; F::GROUP];
            for (i, grow) in band.chunks_mut(gpr).enumerate() {
                let row = m.row(first_row + i);
                for (g, group) in grow.iter_mut().enumerate() {
                    let start = g * F::GROUP;
                    let end = (start + F::GROUP).min(m.cols);
                    buf[..end - start].copy_from_slice(&row[start..end]);
                    buf[end - start..].fill(0.0);
                    *group = F::quantize_group(&buf, mode);
                }
            }
        });
        QuantMat { rows: m.rows, cols: m.cols, groups_per_row: gpr, groups }
    }

    /// Check the rows/cols/groups bookkeeping is self-consistent: every
    /// row carries `cols.div_ceil(GROUP)` groups (ragged tails are
    /// zero-padded at quantize time — the single supported tail
    /// handling). Every consumer that walks the group plane calls this,
    /// so a hand-built matrix with a missing or surplus tail group fails
    /// loudly and identically everywhere.
    pub fn assert_geometry(&self) {
        let need = self.cols.div_ceil(F::GROUP);
        assert_eq!(
            self.groups_per_row,
            need,
            "{} matrix geometry: {} cols need {} groups/row ({}-element groups, padded tail), \
             got {}",
            F::KIND,
            self.cols,
            need,
            F::GROUP,
            self.groups_per_row
        );
        assert_eq!(
            self.groups.len(),
            self.rows * self.groups_per_row,
            "{} matrix geometry: {}×{} rows×groups/row needs {} groups, got {}",
            F::KIND,
            self.rows,
            self.groups_per_row,
            self.rows * self.groups_per_row,
            self.groups.len()
        );
    }

    /// Dequantize back to a dense matrix (zero-padding trimmed),
    /// row-parallel with the process-default thread count.
    pub fn dequantize(&self) -> Matrix {
        let work = self.rows * self.cols * threadpool::DEQUANT_WORK_PER_ELEM;
        self.dequantize_threads(threadpool::threads_for(work))
    }

    /// [`QuantMat::dequantize`] with an explicit thread count.
    pub fn dequantize_threads(&self, threads: usize) -> Matrix {
        self.assert_geometry();
        let mut m = Matrix::zeros(self.rows, self.cols);
        if m.data.is_empty() {
            return m;
        }
        let gpr = self.groups_per_row;
        let cols = self.cols;
        parallel_row_bands(&mut m.data, cols, threads, |first_row, band| {
            let mut buf = vec![0f32; F::GROUP];
            for (i, row) in band.chunks_mut(cols).enumerate() {
                let groups = self.row_groups(first_row + i);
                for g in 0..gpr {
                    F::decode_group(&groups[g], &mut buf);
                    let start = g * F::GROUP;
                    let end = (start + F::GROUP).min(cols);
                    row[start..end].copy_from_slice(&buf[..end - start]);
                }
            }
        });
        m
    }

    /// Serialized wire size in bytes (the format's canonical packed group
    /// layout, padded tail groups included).
    pub fn wire_bytes(&self) -> usize {
        self.groups.len() * F::KIND.wire_bytes_group()
    }

    #[inline]
    pub fn row_groups(&self, r: usize) -> &[F::Group] {
        &self.groups[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }
}

/// A [`QuantMat`] re-laid-out as decode-once integer operand planes: per
/// group, `GROUP` contiguous micro-exponent-absorbed `i8` lanes plus the
/// exact `f64` scale. Packing costs O(rows·cols) once; planes are reused
/// across any number of GEMM calls (the model's real-quantized linears
/// keep weight planes alive across every token).
#[derive(Debug, Clone)]
pub struct PackedQuantMat<F: BlockFormat> {
    pub rows: usize,
    pub cols: usize,
    pub groups_per_row: usize,
    lanes: Vec<i8>,
    scales: Vec<f64>,
    _fmt: PhantomData<F>,
}

impl<F: BlockFormat> PackedQuantMat<F> {
    /// Pack with the process-default thread count (rows pack
    /// independently, so the result is identical for any count).
    pub fn pack(q: &QuantMat<F>) -> PackedQuantMat<F> {
        Self::pack_threads(q, threadpool::threads_for(q.rows * q.cols * PACK_WORK_PER_ELEM))
    }

    /// [`PackedQuantMat::pack`] with an explicit thread count.
    pub fn pack_threads(q: &QuantMat<F>, threads: usize) -> PackedQuantMat<F> {
        q.assert_geometry();
        let gpr = q.groups_per_row;
        let n = q.rows * gpr;
        let mut lanes = vec![0i8; n * F::GROUP];
        let mut scales = vec![0f64; n];
        if n > 0 {
            let lane_stride = gpr * F::GROUP;
            parallel_row_bands2(
                &mut lanes,
                lane_stride,
                &mut scales,
                gpr,
                threads,
                |first_row, lb, sb| {
                    for (i, (lrow, srow)) in
                        lb.chunks_mut(lane_stride).zip(sb.chunks_mut(gpr)).enumerate()
                    {
                        let groups = q.row_groups(first_row + i);
                        for ((lg, s), g) in
                            lrow.chunks_mut(F::GROUP).zip(srow.iter_mut()).zip(groups)
                        {
                            *s = F::group_plane(g, lg);
                        }
                    }
                },
            );
        }
        PackedQuantMat {
            rows: q.rows,
            cols: q.cols,
            groups_per_row: gpr,
            lanes,
            scales,
            _fmt: PhantomData,
        }
    }

    /// Quantize + pack in one step (convenience for activation operands).
    pub fn quantize(m: &Matrix, mode: RoundMode) -> PackedQuantMat<F> {
        Self::pack(&QuantMat::quantize(m, mode))
    }

    /// Lane plane of row `r` (`groups_per_row × GROUP` lanes).
    #[inline]
    pub fn row_lanes(&self, r: usize) -> &[i8] {
        let stride = self.groups_per_row * F::GROUP;
        &self.lanes[r * stride..(r + 1) * stride]
    }

    /// Scale plane of row `r` (one entry per K group).
    #[inline]
    pub fn row_scales(&self, r: usize) -> &[f64] {
        &self.scales[r * self.groups_per_row..(r + 1) * self.groups_per_row]
    }

    /// Wire size of the unit form the planes were packed from.
    pub fn wire_bytes(&self) -> usize {
        self.scales.len() * F::KIND.wire_bytes_group()
    }

    /// One group-pair partial against another packed matrix —
    /// bit-identical to [`BlockFormat::dot_flow`] on the corresponding
    /// groups (pinned by `tests/packed_parity.rs`).
    pub fn dot_group(
        &self,
        r: usize,
        g: usize,
        other: &PackedQuantMat<F>,
        ro: usize,
        go: usize,
    ) -> f64 {
        let ia = &self.row_lanes(r)[g * F::GROUP..(g + 1) * F::GROUP];
        let ib = &other.row_lanes(ro)[go * F::GROUP..(go + 1) * F::GROUP];
        let sp = self.row_scales(r)[g] * other.row_scales(ro)[go];
        sp * (lanes_idot_exact(ia, ib) as f64) / (F::LANE_UNIT * F::LANE_UNIT)
    }
}

/// Largest lane count for which a single `i32` accumulator provably
/// cannot overflow: every `i8×i8` product has magnitude ≤ 128² = 16384
/// (`i8::MIN · i8::MIN` — the extreme, larger than 127²), so
/// `⌊i32::MAX / 16384⌋` = 131 071 products always fit.
///
/// **Overflow audit** (the reason the per-group kernels stay on `i32`):
/// a group reduction spans at most 64 lanes, and the worst in-tree lane
/// magnitudes are 28 (HiF4), 12 (NVFP4/MXFP4), 7 (BFP) and 6 (MX4), so
/// the largest group dot any codec can produce is 64·28² = 50 176 —
/// five orders of magnitude inside `i32`, and still safe (64·128² =
/// 1 048 576) for arbitrary `i8` lanes including `i8::MIN`.
/// *Cross-group* accumulation never happens in integers: each group's
/// dot meets its `f64` scales immediately (scales differ per group), so
/// the only way to approach this bound is a single flat span of more
/// than 131 071 lanes — which [`lanes_idot_exact`] handles by widening
/// to `i64`.
pub const IDOT_I32_SAFE_LANES: usize = (i32::MAX / (128 * 128)) as usize;

/// Straight `i8 × i8 → i32` integer dot over one group's lanes — the
/// entire fixed-point part of a group-pair partial. Integer adds are
/// associative, so the optimizer is free to vectorize; the result is
/// exact either way. Callers pass group-sized spans, far below the
/// [`IDOT_I32_SAFE_LANES`] overflow bound (debug-asserted).
///
/// BOUND: spans ≤ [`IDOT_I32_SAFE_LANES`]; anything longer must go
/// through [`lanes_idot_exact`].
#[inline]
fn lanes_idot(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(a.len() <= IDOT_I32_SAFE_LANES, "span too long for an i32 accumulator");
    let mut acc = 0i32;
    for (x, y) in a.iter().zip(b) {
        acc += (*x as i32) * (*y as i32);
    }
    acc
}

/// Exact integer dot over a lane span of **any** length: group-sized
/// spans (every GEMM/KV call) reduce in a single `i32` chunk; spans past
/// [`IDOT_I32_SAFE_LANES`] — reachable only for whole-K-row reductions
/// with adversarial max-magnitude lanes — accumulate per-chunk `i32`
/// partials into an `i64` total, so the result can never wrap
/// (regression-tested with `i8::MIN` lanes beyond the bound, and
/// end-to-end at `k ≥ 16384` in `tests/packed_parity.rs`).
pub fn lanes_idot_exact(a: &[i8], b: &[i8]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    let mut total = 0i64;
    for (ca, cb) in a.chunks(IDOT_I32_SAFE_LANES).zip(b.chunks(IDOT_I32_SAFE_LANES)) {
        total += lanes_idot(ca, cb) as i64;
    }
    total
}

/// Balanced power-of-two reduction of `pe` partials — `(p0+p1)+(p2+p3)`
/// for `pe = 4` (the [`nvfp4_flow::dot64`] tree), the bare partial for
/// `pe = 1`.
#[inline]
fn pe_tree(pe: usize, partial: impl Fn(usize) -> f64) -> f64 {
    debug_assert!(pe.is_power_of_two() && pe <= 8);
    let mut p = [0f64; 8];
    for (t, slot) in p[..pe].iter_mut().enumerate() {
        *slot = partial(t);
    }
    let mut width = pe;
    while width > 1 {
        width /= 2;
        for t in 0..width {
            p[t] = p[2 * t] + p[2 * t + 1];
        }
    }
    p[0]
}

// ---------------------------------------------------------------------------
// The generic GEMM kernels
// ---------------------------------------------------------------------------

/// `C = A · Bᵀ` through the reference flow kernel: every group pair runs
/// the element-wise fixed-point partial ([`BlockFormat::dot_flow`]),
/// cache-blocked (JB × UB panels) and row-parallel. Bit-identical for
/// every thread count.
pub fn qgemm_bt_flow_threads<F: BlockFormat>(
    a: &QuantMat<F>,
    b_t: &QuantMat<F>,
    threads: usize,
) -> Matrix {
    a.assert_geometry();
    b_t.assert_geometry();
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    // Always-on (a debug-only check would vanish in release, and a PE
    // window straddling a K-block edge silently changes the FP
    // association): UB must be a PE multiple so the blocked schedule
    // issues exactly the flat left-to-right walk's PE sequence.
    let pe = F::GROUPS_PER_PE;
    assert!(UB % pe == 0, "UB ({UB}) must be a multiple of {} PE groups ({pe})", F::KIND);
    let (n, gpr) = (b_t.rows, a.groups_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [0f64; JB];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            for i in 0..rows {
                let ag = a.row_groups(first_row + i);
                accs[..jb].fill(0.0);
                // K-blocked: a JB × UB panel of B groups stays hot while
                // the A row streams; accumulation per (i, j) remains
                // ascending-K with the per-format PE tree inside.
                for u0 in (0..gpr).step_by(UB) {
                    let u1 = (u0 + UB).min(gpr);
                    for (jj, acc) in accs[..jb].iter_mut().enumerate() {
                        let bg = b_t.row_groups(j0 + jj);
                        let mut g = u0;
                        while g + pe <= u1 {
                            *acc += pe_tree(pe, |t| F::dot_flow(&ag[g + t], &bg[g + t]));
                            g += pe;
                        }
                        while g < u1 {
                            // Tail groups stay on the single-group
                            // fixed-point path.
                            *acc += F::dot_flow(&ag[g], &bg[g]);
                            g += 1;
                        }
                    }
                }
                let crow = &mut band[i * n..(i + 1) * n];
                for (jj, acc) in accs[..jb].iter().enumerate() {
                    crow[j0 + jj] = *acc as f32;
                }
            }
        }
    });
    c
}

/// `C = A · Bᵀ` over decode-once packed planes — the fast path, bit-
/// identical to [`qgemm_bt_flow_threads`] on the matrices the planes were
/// packed from (same blocking, same PE tree, same ascending-K order).
pub fn qgemm_bt_packed_threads<F: BlockFormat>(
    a: &PackedQuantMat<F>,
    b_t: &PackedQuantMat<F>,
    threads: usize,
) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    // Always-on (a debug-only check would vanish in release, and a PE
    // window straddling a K-block edge silently changes the FP
    // association): UB must be a PE multiple so the blocked schedule
    // issues exactly the flat left-to-right walk's PE sequence.
    let pe = F::GROUPS_PER_PE;
    assert!(UB % pe == 0, "UB ({UB}) must be a multiple of {} PE groups ({pe})", F::KIND);
    let denom = F::LANE_UNIT * F::LANE_UNIT;
    let (n, gpr) = (b_t.rows, a.groups_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [0f64; JB];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            for i in 0..rows {
                let al = a.row_lanes(first_row + i);
                let asc = a.row_scales(first_row + i);
                accs[..jb].fill(0.0);
                for u0 in (0..gpr).step_by(UB) {
                    let u1 = (u0 + UB).min(gpr);
                    for (jj, acc) in accs[..jb].iter_mut().enumerate() {
                        let bl = b_t.row_lanes(j0 + jj);
                        let bsc = b_t.row_scales(j0 + jj);
                        // One group's partial: the flow's final stage, op
                        // for op — (sa·sb) · Σ lanes / LANE_UNIT².
                        let partial = |g: usize| -> f64 {
                            let ia = &al[g * F::GROUP..(g + 1) * F::GROUP];
                            let ib = &bl[g * F::GROUP..(g + 1) * F::GROUP];
                            (asc[g] * bsc[g]) * (lanes_idot(ia, ib) as f64) / denom
                        };
                        let mut g = u0;
                        while g + pe <= u1 {
                            *acc += pe_tree(pe, |t| partial(g + t));
                            g += pe;
                        }
                        while g < u1 {
                            *acc += partial(g);
                            g += 1;
                        }
                    }
                }
                let crow = &mut band[i * n..(i + 1) * n];
                for (jj, acc) in accs[..jb].iter().enumerate() {
                    crow[j0 + jj] = *acc as f32;
                }
            }
        }
    });
    c
}

// ---------------------------------------------------------------------------
// The SIMD-tiled microkernel backend
// ---------------------------------------------------------------------------

/// Output rows per register tile of the SIMD backend's microkernel.
pub const MR: usize = 2;
/// Output columns per register tile.
pub const NR: usize = 4;
/// Largest [`BlockFormat::GROUPS_PER_PE`] the PE-window buffers size for
/// (matches [`pe_tree`]'s bound).
const MAX_PE: usize = 8;

/// One lane ISA's exact integer microkernels. Every method computes
/// plain `i8·i8→i32` group dots — bit-identical to [`lanes_idot`] by
/// integer associativity — shaped for register reuse: `dot_1x4` loads
/// each A chunk once for [`NR`] B rows, `dot_2x4` additionally loads
/// each B chunk once for [`MR`] A rows.
trait LaneKernel: Send + Sync + 'static {
    /// Exact dot over one group's lanes.
    fn dot(a: &[i8], b: &[i8]) -> i32;
    /// One A group against [`NR`] B groups.
    fn dot_1x4(a: &[i8], b: [&[i8]; NR]) -> [i32; NR];
    /// [`MR`] A groups against [`NR`] B groups — the full register tile.
    fn dot_2x4(a0: &[i8], a1: &[i8], b: [&[i8]; NR]) -> [[i32; NR]; MR];
}

/// Portable unrolled-scalar lane dot: four independent `i32` accumulator
/// chains merged by a balanced final reduction — exact under integer
/// associativity, and the shape LLVM auto-vectorizes well. The SIMD
/// backend's fallback on machines without AVX2.
///
/// BOUND: spans ≤ [`IDOT_I32_SAFE_LANES`] (debug-asserted); anything
/// longer must go through [`lanes_idot_exact`].
#[inline]
fn idot_unrolled(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(a.len() <= IDOT_I32_SAFE_LANES, "span too long for an i32 accumulator");
    let n = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    let mut i = 0;
    while i + 4 <= n {
        s0 += (a[i] as i32) * (b[i] as i32);
        s1 += (a[i + 1] as i32) * (b[i + 1] as i32);
        s2 += (a[i + 2] as i32) * (b[i + 2] as i32);
        s3 += (a[i + 3] as i32) * (b[i + 3] as i32);
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += (a[i] as i32) * (b[i] as i32);
        i += 1;
    }
    s
}

/// The portable [`LaneKernel`]: unrolled scalar chains, no CPU features.
struct PortableKernel;

impl LaneKernel for PortableKernel {
    #[inline]
    fn dot(a: &[i8], b: &[i8]) -> i32 {
        idot_unrolled(a, b)
    }

    #[inline]
    fn dot_1x4(a: &[i8], b: [&[i8]; NR]) -> [i32; NR] {
        [
            idot_unrolled(a, b[0]),
            idot_unrolled(a, b[1]),
            idot_unrolled(a, b[2]),
            idot_unrolled(a, b[3]),
        ]
    }

    #[inline]
    fn dot_2x4(a0: &[i8], a1: &[i8], b: [&[i8]; NR]) -> [[i32; NR]; MR] {
        [Self::dot_1x4(a0, b), Self::dot_1x4(a1, b)]
    }
}

/// `x86_64` AVX2 lane microkernels, selected once at startup by
/// [`crate::dotprod::simd_isa`]. Lanes widen `i8→i16` (`vpmovsxbw`) and
/// multiply-accumulate adjacent pairs into `i32` vector lanes
/// (`vpmaddwd`) — exact for any `i8` inputs: the pairwise products are
/// at most 128² = 16384 each (the `i8::MIN` extreme), their pair sum at
/// most 32 768, and each `i32` vector lane accumulates at most
/// `GROUP/16` pair sums, nowhere near the `i32` range (no
/// `vpmaddubsw`-style saturation anywhere). The horizontal sum
/// therefore equals [`lanes_idot`] bit for bit.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{LaneKernel, MR, NR};
    use std::arch::x86_64::{
        __m128i, __m256i, _mm256_add_epi32, _mm256_castsi256_si128, _mm256_cvtepi8_epi16,
        _mm256_extracti128_si256, _mm256_madd_epi16, _mm256_setzero_si256, _mm_add_epi32,
        _mm_cvtsi128_si32, _mm_loadu_si128, _mm_shuffle_epi32, _mm_unpackhi_epi64,
    };

    /// The AVX2 [`LaneKernel`]. Only instantiated by
    /// [`super::qgemm_bt_simd_threads`] after runtime feature detection
    /// reported AVX2, which is what makes the `unsafe` calls sound.
    pub(super) struct Avx2Kernel;

    impl LaneKernel for Avx2Kernel {
        #[inline]
        fn dot(a: &[i8], b: &[i8]) -> i32 {
            // SAFETY: Avx2Kernel is only selected when AVX2 is detected.
            unsafe { idot(a, b) }
        }

        #[inline]
        fn dot_1x4(a: &[i8], b: [&[i8]; NR]) -> [i32; NR] {
            // SAFETY: Avx2Kernel is only selected when AVX2 is detected.
            unsafe { idot_1x4(a, b) }
        }

        #[inline]
        fn dot_2x4(a0: &[i8], a1: &[i8], b: [&[i8]; NR]) -> [[i32; NR]; MR] {
            // SAFETY: Avx2Kernel is only selected when AVX2 is detected.
            unsafe { idot_2x4(a0, a1, b) }
        }
    }

    /// Widen 16 `i8` lanes at `p[i..i + 16]` to `i16` vector lanes.
    ///
    /// # Safety
    /// AVX2 must be available and `i + 16 <= p.len()`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen16(p: &[i8], i: usize) -> __m256i {
        debug_assert!(i + 16 <= p.len());
        _mm256_cvtepi8_epi16(_mm_loadu_si128(p.as_ptr().add(i) as *const __m128i))
    }

    /// Horizontal sum of the 8 `i32` vector lanes.
    ///
    /// # Safety
    /// AVX2 must be available.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256i) -> i32 {
        let hi: __m128i = _mm256_extracti128_si256::<1>(v);
        let s = _mm_add_epi32(_mm256_castsi256_si128(v), hi);
        let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<1>(s));
        _mm_cvtsi128_si32(s)
    }

    /// Exact `i8` dot over one group's lanes (16-lane vector body plus a
    /// scalar tail; in-tree groups are 16/32/64, so the tail is empty).
    ///
    /// BOUND: callers pass group-sized spans ≤ [`super::IDOT_I32_SAFE_LANES`]
    /// (longer reductions use [`super::lanes_idot_exact`]), so neither
    /// the madd vector accumulators nor the scalar-tail i32 can wrap.
    ///
    /// # Safety
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    unsafe fn idot(a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i + 16 <= n {
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(widen16(a, i), widen16(b, i)));
            i += 16;
        }
        let mut s = hsum(acc);
        while i < n {
            s += (a[i] as i32) * (b[i] as i32);
            i += 1;
        }
        s
    }

    /// One A group against [`NR`] B groups: each A chunk is widened once
    /// and reused across all four B rows (the register-reuse payoff).
    ///
    /// BOUND: callers pass group-sized spans ≤ [`super::IDOT_I32_SAFE_LANES`]
    /// (longer reductions use [`super::lanes_idot_exact`]), so neither
    /// the madd vector accumulators nor the scalar-tail i32 can wrap.
    ///
    /// # Safety
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    unsafe fn idot_1x4(a: &[i8], b: [&[i8]; NR]) -> [i32; NR] {
        let n = a.len();
        let mut acc = [_mm256_setzero_si256(); NR];
        let mut i = 0;
        while i + 16 <= n {
            let wa = widen16(a, i);
            for c in 0..NR {
                debug_assert_eq!(b[c].len(), n);
                acc[c] = _mm256_add_epi32(acc[c], _mm256_madd_epi16(wa, widen16(b[c], i)));
            }
            i += 16;
        }
        let mut out = [0i32; NR];
        for c in 0..NR {
            out[c] = hsum(acc[c]);
        }
        while i < n {
            for c in 0..NR {
                out[c] += (a[i] as i32) * (b[c][i] as i32);
            }
            i += 1;
        }
        out
    }

    /// The full [`MR`]×[`NR`] register tile: A chunks widened once per
    /// [`NR`] columns, B chunks once per [`MR`] rows, eight independent
    /// vector accumulators (2 A + 1 B temp + 8 accumulators = 11 live
    /// `ymm` registers, inside the 16 AVX2 provides).
    ///
    /// BOUND: callers pass group-sized spans ≤ [`super::IDOT_I32_SAFE_LANES`]
    /// (longer reductions use [`super::lanes_idot_exact`]), so neither
    /// the madd vector accumulators nor the scalar-tail i32 can wrap.
    ///
    /// # Safety
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    unsafe fn idot_2x4(a0: &[i8], a1: &[i8], b: [&[i8]; NR]) -> [[i32; NR]; MR] {
        debug_assert_eq!(a0.len(), a1.len());
        let n = a0.len();
        let mut acc = [[_mm256_setzero_si256(); NR]; MR];
        let mut i = 0;
        while i + 16 <= n {
            let wa0 = widen16(a0, i);
            let wa1 = widen16(a1, i);
            for c in 0..NR {
                debug_assert_eq!(b[c].len(), n);
                let wb = widen16(b[c], i);
                acc[0][c] = _mm256_add_epi32(acc[0][c], _mm256_madd_epi16(wa0, wb));
                acc[1][c] = _mm256_add_epi32(acc[1][c], _mm256_madd_epi16(wa1, wb));
            }
            i += 16;
        }
        let mut out = [[0i32; NR]; MR];
        for r in 0..MR {
            for c in 0..NR {
                out[r][c] = hsum(acc[r][c]);
            }
        }
        while i < n {
            for c in 0..NR {
                out[0][c] += (a0[i] as i32) * (b[c][i] as i32);
                out[1][c] += (a1[i] as i32) * (b[c][i] as i32);
            }
            i += 1;
        }
        out
    }
}

/// The four B-row lane slices of group `g`.
#[inline]
fn b_group_slices<'a>(bl: [&'a [i8]; NR], g: usize, gs: usize) -> [&'a [i8]; NR] {
    [
        &bl[0][g * gs..(g + 1) * gs],
        &bl[1][g * gs..(g + 1) * gs],
        &bl[2][g * gs..(g + 1) * gs],
        &bl[3][g * gs..(g + 1) * gs],
    ]
}

/// Integer dots of group `g` across the register tile (`ra` ∈ {1, 2}
/// live A rows; a 1-row tail leaves the second result row zeroed and
/// unread).
#[inline]
fn tile_dots<K: LaneKernel>(
    ra: usize,
    al: [&[i8]; MR],
    g: usize,
    gs: usize,
    gb: [&[i8]; NR],
) -> [[i32; NR]; MR] {
    let ga0 = &al[0][g * gs..(g + 1) * gs];
    if ra == MR {
        K::dot_2x4(ga0, &al[1][g * gs..(g + 1) * gs], gb)
    } else {
        [K::dot_1x4(ga0, gb), [0i32; NR]]
    }
}

/// One register tile (`ra` A rows × [`NR`] B rows) against one K block
/// (groups `u0..u1`): integer dots through the lane microkernel, then
/// per output element the **identical** `f64` op sequence the scalar
/// packed kernel performs — ascending K, the per-format PE tree — so the
/// backends stay bit-identical.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_update<F: BlockFormat, K: LaneKernel>(
    ra: usize,
    al: [&[i8]; MR],
    asc: [&[f64]; MR],
    bl: [&[i8]; NR],
    bsc: [&[f64]; NR],
    u0: usize,
    u1: usize,
    denom: f64,
    accs: &mut [[f64; JB]; MR],
    jj: usize,
) {
    let pe = F::GROUPS_PER_PE;
    let gs = F::GROUP;
    if pe == 1 {
        // Direct ascending accumulation (HiF4/MXFP4/MX4/BFP).
        for g in u0..u1 {
            let w = tile_dots::<K>(ra, al, g, gs, b_group_slices(bl, g, gs));
            for r in 0..ra {
                for (c, wc) in w[r].iter().enumerate() {
                    accs[r][jj + c] += (asc[r][g] * bsc[c][g]) * (*wc as f64) / denom;
                }
            }
        }
        return;
    }
    // PE windows (NVFP4): gather the window's tile dots, then reduce
    // each output element through the same balanced tree as the scalar
    // kernel, in the same ascending-K window order.
    let mut g = u0;
    while g + pe <= u1 {
        let mut w = [[[0i32; NR]; MR]; MAX_PE];
        for (t, wt) in w[..pe].iter_mut().enumerate() {
            let gt = g + t;
            *wt = tile_dots::<K>(ra, al, gt, gs, b_group_slices(bl, gt, gs));
        }
        for r in 0..ra {
            for c in 0..NR {
                accs[r][jj + c] += pe_tree(pe, |t| {
                    (asc[r][g + t] * bsc[c][g + t]) * (w[t][r][c] as f64) / denom
                });
            }
        }
        g += pe;
    }
    // K tail that doesn't fill a PE: single-group fixed-point partials.
    while g < u1 {
        let w = tile_dots::<K>(ra, al, g, gs, b_group_slices(bl, g, gs));
        for r in 0..ra {
            for (c, wc) in w[r].iter().enumerate() {
                accs[r][jj + c] += (asc[r][g] * bsc[c][g]) * (*wc as f64) / denom;
            }
        }
        g += 1;
    }
}

/// Column tail of a tile row-set: `ra` A rows against a single B row,
/// exactly the scalar packed kernel's per-element schedule with the lane
/// microkernel's single-group dot.
#[allow(clippy::too_many_arguments)]
#[inline]
fn col_update<F: BlockFormat, K: LaneKernel>(
    ra: usize,
    al: [&[i8]; MR],
    asc: [&[f64]; MR],
    bl: &[i8],
    bsc: &[f64],
    u0: usize,
    u1: usize,
    denom: f64,
    accs: &mut [[f64; JB]; MR],
    jj: usize,
) {
    let pe = F::GROUPS_PER_PE;
    let gs = F::GROUP;
    for r in 0..ra {
        let acc = &mut accs[r][jj];
        let partial = |g: usize| -> f64 {
            let ia = &al[r][g * gs..(g + 1) * gs];
            let ib = &bl[g * gs..(g + 1) * gs];
            (asc[r][g] * bsc[g]) * (K::dot(ia, ib) as f64) / denom
        };
        let mut g = u0;
        while g + pe <= u1 {
            *acc += pe_tree(pe, |t| partial(g + t));
            g += pe;
        }
        while g < u1 {
            *acc += partial(g);
            g += 1;
        }
    }
}

/// `C = A · Bᵀ` through the register-tiled microkernel over one lane
/// ISA — same blocking, PE tree and ascending-K order as
/// [`qgemm_bt_packed_threads`], so outputs are bit-identical to it (and
/// to the flow) for every thread count.
fn qgemm_bt_tiled_threads<F: BlockFormat, K: LaneKernel>(
    a: &PackedQuantMat<F>,
    b_t: &PackedQuantMat<F>,
    threads: usize,
) -> Matrix {
    assert_eq!(a.cols, b_t.cols, "reduction dims must agree");
    // Always-on (a debug-only check would vanish in release, and a PE
    // window straddling a K-block edge silently changes the FP
    // association): UB must be a PE multiple so the blocked schedule
    // issues exactly the flat left-to-right walk's PE sequence.
    let pe = F::GROUPS_PER_PE;
    assert!(UB % pe == 0, "UB ({UB}) must be a multiple of {} PE groups ({pe})", F::KIND);
    let denom = F::LANE_UNIT * F::LANE_UNIT;
    let (n, gpr) = (b_t.rows, a.groups_per_row);
    let mut c = Matrix::zeros(a.rows, n);
    if a.rows == 0 || n == 0 {
        return c;
    }
    parallel_row_bands(&mut c.data, n, threads, |first_row, band| {
        let rows = band.len() / n;
        let mut accs = [[0f64; JB]; MR];
        for j0 in (0..n).step_by(JB) {
            let jb = (j0 + JB).min(n) - j0;
            let mut i = 0;
            while i < rows {
                let ra = (i + MR).min(rows) - i;
                // A 1-row tail aliases the same row into both tile slots;
                // the second slot's results are never read.
                let al = [a.row_lanes(first_row + i), a.row_lanes(first_row + i + ra - 1)];
                let asc = [a.row_scales(first_row + i), a.row_scales(first_row + i + ra - 1)];
                for acc in accs.iter_mut() {
                    acc[..jb].fill(0.0);
                }
                for u0 in (0..gpr).step_by(UB) {
                    let u1 = (u0 + UB).min(gpr);
                    let mut jj = 0;
                    while jj + NR <= jb {
                        let bl = [
                            b_t.row_lanes(j0 + jj),
                            b_t.row_lanes(j0 + jj + 1),
                            b_t.row_lanes(j0 + jj + 2),
                            b_t.row_lanes(j0 + jj + 3),
                        ];
                        let bsc = [
                            b_t.row_scales(j0 + jj),
                            b_t.row_scales(j0 + jj + 1),
                            b_t.row_scales(j0 + jj + 2),
                            b_t.row_scales(j0 + jj + 3),
                        ];
                        tile_update::<F, K>(ra, al, asc, bl, bsc, u0, u1, denom, &mut accs, jj);
                        jj += NR;
                    }
                    while jj < jb {
                        col_update::<F, K>(
                            ra,
                            al,
                            asc,
                            b_t.row_lanes(j0 + jj),
                            b_t.row_scales(j0 + jj),
                            u0,
                            u1,
                            denom,
                            &mut accs,
                            jj,
                        );
                        jj += 1;
                    }
                }
                for r in 0..ra {
                    let crow = &mut band[(i + r) * n..(i + r + 1) * n];
                    for (jx, acc) in accs[r][..jb].iter().enumerate() {
                        crow[j0 + jx] = *acc as f32;
                    }
                }
                i += ra;
            }
        }
    });
    c
}

/// `C = A · Bᵀ` through the SIMD-tiled backend: dispatches once to the
/// lane ISA [`super::simd_isa`] detected at startup (AVX2 on `x86_64`
/// CPUs that have it, the portable unrolled microkernel otherwise) and
/// runs the [`MR`]×[`NR`] register-tiled schedule. Bit-identical to
/// [`qgemm_bt_packed_threads`] and [`qgemm_bt_flow_threads`] on the
/// matrices the planes were packed from, for every thread count.
pub fn qgemm_bt_simd_threads<F: BlockFormat>(
    a: &PackedQuantMat<F>,
    b_t: &PackedQuantMat<F>,
    threads: usize,
) -> Matrix {
    match super::simd_isa() {
        #[cfg(target_arch = "x86_64")]
        SimdIsa::Avx2 => qgemm_bt_tiled_threads::<F, avx2::Avx2Kernel>(a, b_t, threads),
        #[cfg(not(target_arch = "x86_64"))]
        SimdIsa::Avx2 => unreachable!("AVX2 is only ever detected on x86_64"),
        SimdIsa::Portable => qgemm_bt_tiled_threads::<F, PortableKernel>(a, b_t, threads),
    }
}

/// One exact `i8·i8 → i32` group dot through the startup-detected lane
/// ISA's `LaneKernel` — the integer `QK^T` primitive of the fused
/// attention path ([`crate::model::attention`]), which scores query
/// lanes against the KV cache's packed planes without dequantizing
/// them. Exact for any `i8` contents (both ISAs widen before
/// multiplying; see the overflow audit at [`IDOT_I32_SAFE_LANES`]), so
/// callers may feed full 8-bit lanes, not just the 4-bit codec range.
/// Spans must be one group (every format group is a 16-lane multiple,
/// which the AVX2 kernel requires).
pub fn lane_dot(a: &[i8], b: &[i8]) -> i32 {
    match super::simd_isa() {
        #[cfg(target_arch = "x86_64")]
        SimdIsa::Avx2 => avx2::Avx2Kernel::dot(a, b),
        #[cfg(not(target_arch = "x86_64"))]
        SimdIsa::Avx2 => unreachable!("AVX2 is only ever detected on x86_64"),
        SimdIsa::Portable => PortableKernel::dot(a, b),
    }
}

/// [`lane_dot`] of one query group against [`NR`] key groups — the
/// register-reuse shape the fused attention tile loop scores with: the
/// query operand is widened once per four key rows, exactly as in the
/// QGEMM microkernel's `dot_1x4` pass. Each result is bit-identical to
/// the corresponding [`lane_dot`] (integer adds are associative).
pub fn lane_dot_1x4(a: &[i8], b: [&[i8]; NR]) -> [i32; NR] {
    match super::simd_isa() {
        #[cfg(target_arch = "x86_64")]
        SimdIsa::Avx2 => avx2::Avx2Kernel::dot_1x4(a, b),
        #[cfg(not(target_arch = "x86_64"))]
        SimdIsa::Avx2 => unreachable!("AVX2 is only ever detected on x86_64"),
        SimdIsa::Portable => PortableKernel::dot_1x4(a, b),
    }
}

/// `LANE_UNIT` of `kind`'s codec — the power-of-two lane quantum
/// denominator: plane values decode as `scale · lane / LANE_UNIT`.
/// Dispatch helper for consumers that hold a runtime [`QuantKind`]
/// rather than a `BlockFormat` type parameter (the fused attention
/// kernel's score scaling).
pub fn lane_unit(kind: QuantKind) -> f64 {
    match kind {
        QuantKind::HiF4 => HiF4Fmt::LANE_UNIT,
        QuantKind::Nvfp4 => Nvfp4Fmt::LANE_UNIT,
        QuantKind::Mxfp4 => Mxfp4Fmt::LANE_UNIT,
        QuantKind::Mx4 => Mx4Fmt::LANE_UNIT,
        QuantKind::Bfp => BfpFmt::LANE_UNIT,
    }
}

/// The dequantized-f64 reference partial for one group pair: decode both
/// groups and walk the products in ascending element order. Every codec's
/// flow/packed partials equal this bit for bit (each term is a small
/// dyadic rational, so the f64 walk is exact).
pub fn dot_dequant_ref<F: BlockFormat>(a: &F::Group, b: &F::Group) -> f64 {
    let mut da = vec![0f32; F::GROUP];
    let mut db = vec![0f32; F::GROUP];
    F::decode_group(a, &mut da);
    F::decode_group(b, &mut db);
    let mut acc = 0f64;
    for (x, y) in da.iter().zip(&db) {
        acc += (*x as f64) * (*y as f64);
    }
    acc
}

// ---------------------------------------------------------------------------
// The enum-dispatched surface
// ---------------------------------------------------------------------------

macro_rules! dispatch {
    ($self:expr, $m:ident => $body:expr) => {
        match $self {
            Self::HiF4($m) => $body,
            Self::Nvfp4($m) => $body,
            Self::Mxfp4($m) => $body,
            Self::Mx4($m) => $body,
            Self::Bfp($m) => $body,
        }
    };
}

macro_rules! dispatch_pair {
    ($a:expr, $b:expr, $x:ident, $y:ident => $body:expr, $op:literal) => {
        match ($a, $b) {
            (Self::HiF4($x), Self::HiF4($y)) => $body,
            (Self::Nvfp4($x), Self::Nvfp4($y)) => $body,
            (Self::Mxfp4($x), Self::Mxfp4($y)) => $body,
            (Self::Mx4($x), Self::Mx4($y)) => $body,
            (Self::Bfp($x), Self::Bfp($y)) => $body,
            (x, y) => panic!(
                concat!($op, " operands must share a format, got {} vs {}"),
                x.kind(),
                y.kind()
            ),
        }
    };
}

/// A matrix quantized in any of the five block formats — the single
/// quantized-tensor type every consumer programs against. Construct with
/// [`QuantizedMatrix::quantize`]; run GEMMs with
/// [`QuantizedMatrix::qgemm_bt`] (kernel-backend dispatching) or pack
/// once with [`QuantizedMatrix::pack`] and reuse the planes.
#[derive(Debug, Clone)]
pub enum QuantizedMatrix {
    HiF4(QuantMat<HiF4Fmt>),
    Nvfp4(QuantMat<Nvfp4Fmt>),
    Mxfp4(QuantMat<Mxfp4Fmt>),
    Mx4(QuantMat<Mx4Fmt>),
    Bfp(QuantMat<BfpFmt>),
}

impl QuantizedMatrix {
    /// Quantize a row-major matrix in `kind` (row-parallel, process-
    /// default thread count).
    pub fn quantize(kind: QuantKind, m: &Matrix, mode: RoundMode) -> QuantizedMatrix {
        let work = m.rows * m.cols * threadpool::QUANT_WORK_PER_ELEM;
        Self::quantize_threads(kind, m, mode, threadpool::threads_for(work))
    }

    /// [`QuantizedMatrix::quantize`] with an explicit thread count
    /// (identical output for any count).
    pub fn quantize_threads(
        kind: QuantKind,
        m: &Matrix,
        mode: RoundMode,
        threads: usize,
    ) -> QuantizedMatrix {
        match kind {
            QuantKind::HiF4 => Self::HiF4(QuantMat::quantize_threads(m, mode, threads)),
            QuantKind::Nvfp4 => Self::Nvfp4(QuantMat::quantize_threads(m, mode, threads)),
            QuantKind::Mxfp4 => Self::Mxfp4(QuantMat::quantize_threads(m, mode, threads)),
            QuantKind::Mx4 => Self::Mx4(QuantMat::quantize_threads(m, mode, threads)),
            QuantKind::Bfp => Self::Bfp(QuantMat::quantize_threads(m, mode, threads)),
        }
    }

    /// The block format this matrix is quantized in.
    pub fn kind(&self) -> QuantKind {
        match self {
            Self::HiF4(_) => QuantKind::HiF4,
            Self::Nvfp4(_) => QuantKind::Nvfp4,
            Self::Mxfp4(_) => QuantKind::Mxfp4,
            Self::Mx4(_) => QuantKind::Mx4,
            Self::Bfp(_) => QuantKind::Bfp,
        }
    }

    pub fn rows(&self) -> usize {
        dispatch!(self, m => m.rows)
    }

    pub fn cols(&self) -> usize {
        dispatch!(self, m => m.cols)
    }

    pub fn groups_per_row(&self) -> usize {
        dispatch!(self, m => m.groups_per_row)
    }

    /// Uniform geometry check (see [`QuantMat::assert_geometry`]).
    pub fn assert_geometry(&self) {
        dispatch!(self, m => m.assert_geometry())
    }

    /// Serialized wire size in bytes.
    pub fn wire_bytes(&self) -> usize {
        dispatch!(self, m => m.wire_bytes())
    }

    /// Dequantize back to a dense matrix.
    pub fn dequantize(&self) -> Matrix {
        dispatch!(self, m => m.dequantize())
    }

    /// [`QuantizedMatrix::dequantize`] with an explicit thread count.
    pub fn dequantize_threads(&self, threads: usize) -> Matrix {
        dispatch!(self, m => m.dequantize_threads(threads))
    }

    /// Pack into decode-once integer operand planes.
    pub fn pack(&self) -> PackedQuantizedMatrix {
        self.pack_threads(threadpool::threads_for(self.rows() * self.cols() * PACK_WORK_PER_ELEM))
    }

    /// [`QuantizedMatrix::pack`] with an explicit thread count.
    pub fn pack_threads(&self, threads: usize) -> PackedQuantizedMatrix {
        match self {
            Self::HiF4(m) => PackedQuantizedMatrix::HiF4(PackedQuantMat::pack_threads(m, threads)),
            Self::Nvfp4(m) => {
                PackedQuantizedMatrix::Nvfp4(PackedQuantMat::pack_threads(m, threads))
            }
            Self::Mxfp4(m) => {
                PackedQuantizedMatrix::Mxfp4(PackedQuantMat::pack_threads(m, threads))
            }
            Self::Mx4(m) => PackedQuantizedMatrix::Mx4(PackedQuantMat::pack_threads(m, threads)),
            Self::Bfp(m) => PackedQuantizedMatrix::Bfp(PackedQuantMat::pack_threads(m, threads)),
        }
    }

    /// `C = self · b_tᵀ` on the process-wide kernel backend
    /// ([`super::kernel`]; numerically inert — both backends are
    /// bit-identical). Panics if the operands' formats differ.
    pub fn qgemm_bt(&self, b_t: &QuantizedMatrix) -> Matrix {
        let work = self.rows() * b_t.rows() * self.cols();
        self.qgemm_bt_threads(b_t, threadpool::threads_for(work))
    }

    /// [`QuantizedMatrix::qgemm_bt`] with an explicit thread count —
    /// bit-identical for every value.
    pub fn qgemm_bt_threads(&self, b_t: &QuantizedMatrix, threads: usize) -> Matrix {
        match super::kernel() {
            Kernel::Flow => self.qgemm_bt_flow_threads(b_t, threads),
            Kernel::Packed | Kernel::Simd => {
                // One-time O(M·K + N·K) pack, then the integer fast path
                // (the plane backend re-dispatches on the same knob);
                // callers holding operands across calls should pack once
                // themselves ([`QuantizedMatrix::pack`]) to amortize even
                // this.
                self.pack_threads(threads).qgemm_bt_threads(&b_t.pack_threads(threads), threads)
            }
        }
    }

    /// The reference flow-kernel GEMM (process-default threads).
    pub fn qgemm_bt_flow(&self, b_t: &QuantizedMatrix) -> Matrix {
        let work = self.rows() * b_t.rows() * self.cols();
        self.qgemm_bt_flow_threads(b_t, threadpool::threads_for(work))
    }

    /// [`QuantizedMatrix::qgemm_bt_flow`] with an explicit thread count.
    pub fn qgemm_bt_flow_threads(&self, b_t: &QuantizedMatrix, threads: usize) -> Matrix {
        dispatch_pair!(self, b_t, x, y => qgemm_bt_flow_threads(x, y, threads), "flow QGEMM")
    }
}

/// Decode-once packed integer operand planes for any of the five block
/// formats — the fast-path twin of [`QuantizedMatrix`].
#[derive(Debug, Clone)]
pub enum PackedQuantizedMatrix {
    HiF4(PackedQuantMat<HiF4Fmt>),
    Nvfp4(PackedQuantMat<Nvfp4Fmt>),
    Mxfp4(PackedQuantMat<Mxfp4Fmt>),
    Mx4(PackedQuantMat<Mx4Fmt>),
    Bfp(PackedQuantMat<BfpFmt>),
}

impl PackedQuantizedMatrix {
    pub fn kind(&self) -> QuantKind {
        match self {
            Self::HiF4(_) => QuantKind::HiF4,
            Self::Nvfp4(_) => QuantKind::Nvfp4,
            Self::Mxfp4(_) => QuantKind::Mxfp4,
            Self::Mx4(_) => QuantKind::Mx4,
            Self::Bfp(_) => QuantKind::Bfp,
        }
    }

    pub fn rows(&self) -> usize {
        dispatch!(self, m => m.rows)
    }

    pub fn cols(&self) -> usize {
        dispatch!(self, m => m.cols)
    }

    /// Wire size of the unit form the planes were packed from.
    pub fn wire_bytes(&self) -> usize {
        dispatch!(self, m => m.wire_bytes())
    }

    /// `C = self · b_tᵀ` over prepacked planes (process-default threads).
    pub fn qgemm_bt(&self, b_t: &PackedQuantizedMatrix) -> Matrix {
        let work = self.rows() * b_t.rows() * self.cols();
        self.qgemm_bt_threads(b_t, threadpool::threads_for(work))
    }

    /// [`PackedQuantizedMatrix::qgemm_bt`] with an explicit thread count,
    /// on the process-wide kernel backend: the SIMD-tiled microkernel
    /// under [`Kernel::Simd`] (the default), the scalar packed kernel
    /// otherwise ([`Kernel::Flow`] has no plane schedule — its
    /// bit-identical twin on planes is the scalar kernel). Bit-identical
    /// to the flow kernel on the matrices the planes were packed from,
    /// for every thread count and backend.
    pub fn qgemm_bt_threads(&self, b_t: &PackedQuantizedMatrix, threads: usize) -> Matrix {
        match super::kernel() {
            Kernel::Simd => self.qgemm_bt_simd_threads(b_t, threads),
            Kernel::Flow | Kernel::Packed => self.qgemm_bt_packed_threads(b_t, threads),
        }
    }

    /// Force the scalar packed kernel regardless of the process knob
    /// (backend comparisons — the parity suites and `qgemm_throughput`
    /// pin and measure the backends independently).
    pub fn qgemm_bt_packed_threads(&self, b_t: &PackedQuantizedMatrix, threads: usize) -> Matrix {
        dispatch_pair!(self, b_t, x, y => qgemm_bt_packed_threads(x, y, threads), "packed QGEMM")
    }

    /// Force the SIMD-tiled microkernel regardless of the process knob
    /// (ISA per [`super::simd_isa`]).
    pub fn qgemm_bt_simd_threads(&self, b_t: &PackedQuantizedMatrix, threads: usize) -> Matrix {
        dispatch_pair!(self, b_t, x, y => qgemm_bt_simd_threads(x, y, threads), "SIMD QGEMM")
    }
}

// ---------------------------------------------------------------------------
// Row-plane helpers (the KV cache's encode-once layout)
// ---------------------------------------------------------------------------

/// Encode one row into decode-once planes: chunk `row` into `kind`-sized
/// groups (zero-padded tail — the same uniform tail handling as
/// [`QuantMat::quantize`]), quantize each through the format codec, and
/// append the integer lanes + exact `f64` scale. Row-granular twin of
/// [`PackedQuantMat::pack`] for consumers that grow one row at a time
/// (the quantized KV cache).
pub fn encode_row_planes(kind: QuantKind, row: &[f32], lanes: &mut Vec<i8>, scales: &mut Vec<f64>) {
    match kind {
        QuantKind::HiF4 => encode_row_planes_g::<HiF4Fmt>(row, lanes, scales),
        QuantKind::Nvfp4 => encode_row_planes_g::<Nvfp4Fmt>(row, lanes, scales),
        QuantKind::Mxfp4 => encode_row_planes_g::<Mxfp4Fmt>(row, lanes, scales),
        QuantKind::Mx4 => encode_row_planes_g::<Mx4Fmt>(row, lanes, scales),
        QuantKind::Bfp => encode_row_planes_g::<BfpFmt>(row, lanes, scales),
    }
}

fn encode_row_planes_g<F: BlockFormat>(row: &[f32], lanes: &mut Vec<i8>, scales: &mut Vec<f64>) {
    // Stack buffer on the decode hot path (one call per appended KV row):
    // 64 is the largest group across all five codecs.
    debug_assert!(F::GROUP <= 64);
    let mut buf = [0f32; 64];
    let buf = &mut buf[..F::GROUP];
    for u in 0..row.len().div_ceil(F::GROUP) {
        let start = u * F::GROUP;
        let end = (start + F::GROUP).min(row.len());
        buf[..end - start].copy_from_slice(&row[start..end]);
        buf[end - start..].fill(0.0);
        let g = F::quantize_group(&buf, RoundMode::NearestEven);
        let base = lanes.len();
        lanes.resize(base + F::GROUP, 0);
        scales.push(F::group_plane(&g, &mut lanes[base..]));
    }
}

/// Decode the first `out.len()` lanes of one plane group back to f32:
/// `v_i = scale · lane_i / LANE_UNIT` — one multiply per element,
/// bit-identical to the format's own group decode (a NaN scale poisons
/// every element, matching the NaN channel).
pub fn decode_plane(kind: QuantKind, lanes: &[i8], scale: f64, out: &mut [f32]) {
    match kind {
        QuantKind::HiF4 => decode_plane_g::<HiF4Fmt>(lanes, scale, out),
        QuantKind::Nvfp4 => decode_plane_g::<Nvfp4Fmt>(lanes, scale, out),
        QuantKind::Mxfp4 => decode_plane_g::<Mxfp4Fmt>(lanes, scale, out),
        QuantKind::Mx4 => decode_plane_g::<Mx4Fmt>(lanes, scale, out),
        QuantKind::Bfp => decode_plane_g::<BfpFmt>(lanes, scale, out),
    }
}

fn decode_plane_g<F: BlockFormat>(lanes: &[i8], scale: f64, out: &mut [f32]) {
    assert!(
        out.len() <= F::GROUP,
        "{} plane decodes at most {} elements; buffer holds {}",
        F::KIND,
        F::GROUP,
        out.len()
    );
    let s = scale as f32;
    // 1/LANE_UNIT is a power of two: the lane scaling is exact.
    // audit:allow(narrowing) -- 1/LANE_UNIT is an exact power of two; the f64→f32 cast is lossless.
    let recip = (1.0 / F::LANE_UNIT) as f32;
    for (o, lane) in out.iter_mut().zip(lanes) {
        *o = s * (*lane as f32 * recip);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    const MODE: RoundMode = RoundMode::NearestEven;

    #[test]
    fn lane_magnitudes_stay_in_bounds() {
        // The deterministic worst case: every element alternating ±peak,
        // which for HiF4 sets both micro-exponent levels so lanes hit the
        // 7 << 2 = 28 extreme — the bound that makes the i8 plane
        // lossless. Every codec's lanes must respect its documented bound.
        for (kind, bound) in [
            (QuantKind::HiF4, 28i8),
            (QuantKind::Nvfp4, 12),
            (QuantKind::Mxfp4, 12),
            (QuantKind::Mx4, 6),
            (QuantKind::Bfp, 7),
        ] {
            let g = kind.group();
            let v: Vec<f32> =
                (0..g).map(|i| if i % 2 == 0 { 7.0 } else { -7.0 }).collect();
            let mut lanes = Vec::new();
            let mut scales = Vec::new();
            encode_row_planes(kind, &v, &mut lanes, &mut scales);
            assert_eq!(lanes.len(), g);
            assert_eq!(scales.len(), 1);
            for lane in &lanes {
                assert!(lane.abs() <= bound, "{kind}: lane {lane} exceeds {bound}");
            }
        }
    }

    #[test]
    fn quantize_dequantize_matches_scheme_path_all_formats() {
        // The matrix path and the flat QuantScheme path must agree bitwise
        // for every format (same codec, same padded-tail handling).
        use crate::formats::QuantScheme;
        let mut rng = Rng::seed(503);
        let m = Matrix::randn(3, 100, 0.5, &mut rng);
        for kind in QuantKind::ALL {
            let q = QuantizedMatrix::quantize(kind, &m, MODE);
            q.assert_geometry();
            let deq = q.dequantize();
            let scheme = QuantScheme::direct(kind);
            for r in 0..m.rows {
                let flat = scheme.quant_dequant_vec(m.row(r));
                assert_eq!(deq.row(r), &flat[..], "{kind} row {r}");
            }
        }
    }

    #[test]
    fn plane_decode_matches_group_decode_bitwise() {
        // Lane decode (scale · lane / LANE_UNIT) must reproduce the
        // format's own decode exactly, including the NaN channel.
        let mut rng = Rng::seed(505);
        for kind in QuantKind::ALL {
            let g = kind.group();
            for round in 0..40 {
                let sigma = 10f32.powi((round % 8) - 4);
                let v: Vec<f32> = (0..g).map(|_| rng.normal() as f32 * sigma).collect();
                let mut qd = vec![0f32; g];
                kind.quant_dequant_block(&v, &mut qd, MODE);
                let mut lanes = Vec::new();
                let mut scales = Vec::new();
                encode_row_planes(kind, &v, &mut lanes, &mut scales);
                let mut decoded = vec![0f32; g];
                decode_plane(kind, &lanes, scales[0], &mut decoded);
                for (i, (d, want)) in decoded.iter().zip(&qd).enumerate() {
                    assert_eq!(d.to_bits(), want.to_bits(), "{kind} round {round} elem {i}");
                }
            }
            // NaN channel: a poisoned group poisons every decoded lane.
            let mut v = vec![1.0f32; g];
            v[g / 2] = f32::NAN;
            let mut lanes = Vec::new();
            let mut scales = Vec::new();
            encode_row_planes(kind, &v, &mut lanes, &mut scales);
            let mut decoded = vec![0f32; g];
            decode_plane(kind, &lanes, scales[0], &mut decoded);
            assert!(decoded.iter().all(|x| x.is_nan()), "{kind}");
        }
    }

    #[test]
    #[should_panic(expected = "matrix geometry")]
    fn pack_rejects_inconsistent_geometry() {
        let mut rng = Rng::seed(506);
        let mut q = QuantMat::<HiF4Fmt>::quantize(&Matrix::randn(2, 130, 1.0, &mut rng), MODE);
        q.groups_per_row = 1; // lies about the padded tail unit
        let _ = PackedQuantMat::pack_threads(&q, 1);
    }

    #[test]
    #[should_panic(expected = "share a format")]
    fn mismatched_formats_panic_loudly() {
        let mut rng = Rng::seed(507);
        let m = Matrix::randn(2, 64, 1.0, &mut rng);
        let a = QuantizedMatrix::quantize(QuantKind::HiF4, &m, MODE);
        let b = QuantizedMatrix::quantize(QuantKind::Mxfp4, &m, MODE);
        let _ = a.qgemm_bt(&b);
    }

    #[test]
    fn pack_is_thread_count_invariant_all_formats() {
        let mut rng = Rng::seed(504);
        let m = Matrix::randn(9, 200, 1.0, &mut rng);
        for kind in QuantKind::ALL {
            let q = QuantizedMatrix::quantize_threads(kind, &m, MODE, 1);
            let serial = q.pack_threads(1);
            // Probe the raw planes directly on the HiF4 variant; for every
            // kind, identical planes give a bit-identical product.
            let c0 = serial.qgemm_bt_threads(&serial, 1);
            for t in [2, 3, 5] {
                let par = q.pack_threads(t);
                if let (PackedQuantizedMatrix::HiF4(a), PackedQuantizedMatrix::HiF4(b)) =
                    (&serial, &par)
                {
                    for r in 0..q.rows() {
                        assert_eq!(a.row_scales(r), b.row_scales(r), "threads={t}");
                        assert_eq!(a.row_lanes(r), b.row_lanes(r), "threads={t}");
                    }
                }
                let c1 = par.qgemm_bt_threads(&par, 1);
                assert_eq!(c0.data, c1.data, "{kind} threads={t}");
            }
        }
    }

    #[test]
    fn wire_bytes_accounting() {
        let mut rng = Rng::seed(508);
        // 100 cols: ragged tails for every group size.
        let m = Matrix::randn(3, 100, 1.0, &mut rng);
        for kind in QuantKind::ALL {
            let q = QuantizedMatrix::quantize(kind, &m, MODE);
            let groups = 3 * 100usize.div_ceil(kind.group());
            assert_eq!(q.wire_bytes(), groups * kind.wire_bytes_group(), "{kind}");
            assert_eq!(q.pack().wire_bytes(), q.wire_bytes(), "{kind} packed");
        }
    }

    /// Random `i8` lane vector over the FULL `i8` range including the
    /// `i8::MIN` extreme the overflow audit is derived from (harsher
    /// than any codec emits — the microkernels must be exact
    /// regardless).
    fn random_lanes(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| (rng.below(256) as i64 - 128) as i8).collect()
    }

    /// Reference dot in i64 (cannot wrap for these lengths).
    fn idot_ref(a: &[i8], b: &[i8]) -> i64 {
        a.iter().zip(b).map(|(x, y)| (*x as i64) * (*y as i64)).sum()
    }

    #[test]
    fn lane_microkernels_are_exact_for_every_isa() {
        // The portable unrolled kernel — and, where the CPU has it, the
        // AVX2 kernel — must equal the plain i64 reference on every group
        // size (16/32/64) plus odd tail lengths, with full-range lanes.
        let mut rng = Rng::seed(520);
        for n in [16usize, 32, 64, 7, 33] {
            for round in 0..50 {
                let a = random_lanes(&mut rng, n);
                let b = [
                    random_lanes(&mut rng, n),
                    random_lanes(&mut rng, n),
                    random_lanes(&mut rng, n),
                    random_lanes(&mut rng, n),
                ];
                let want: Vec<i64> = b.iter().map(|bc| idot_ref(&a, bc)).collect();
                let ctx = format!("n={n} round={round}");
                assert_eq!(idot_unrolled(&a, &b[0]) as i64, want[0], "unrolled {ctx}");
                let gb = [&b[0][..], &b[1][..], &b[2][..], &b[3][..]];
                let p4 = PortableKernel::dot_1x4(&a, gb);
                let p8 = PortableKernel::dot_2x4(&a, &b[0], gb);
                for c in 0..NR {
                    assert_eq!(p4[c] as i64, want[c], "portable 1x4 {ctx}");
                    assert_eq!(p8[0][c] as i64, want[c], "portable 2x4 row0 {ctx}");
                    assert_eq!(p8[1][c] as i64, idot_ref(&b[0], &b[c]), "portable 2x4 row1 {ctx}");
                }
                #[cfg(target_arch = "x86_64")]
                {
                    if super::super::simd_isa() == SimdIsa::Avx2 {
                        assert_eq!(avx2::Avx2Kernel::dot(&a, &b[0]) as i64, want[0], "avx2 {ctx}");
                        let v4 = avx2::Avx2Kernel::dot_1x4(&a, gb);
                        let v8 = avx2::Avx2Kernel::dot_2x4(&a, &b[0], gb);
                        for c in 0..NR {
                            assert_eq!(v4[c] as i64, want[c], "avx2 1x4 {ctx}");
                            assert_eq!(v8[0][c] as i64, want[c], "avx2 2x4 row0 {ctx}");
                            assert_eq!(
                                v8[1][c] as i64,
                                idot_ref(&b[0], &b[c]),
                                "avx2 2x4 row1 {ctx}"
                            );
                        }
                    }
                }
            }
        }
        // Deterministic vpmaddwd extreme: adjacent (-128)·(-128) pairs
        // sum to 32 768 — one past i16::MAX, the exact value a
        // saturating i16 path (vpmaddubsw-style) would corrupt. Every
        // kernel must reduce it exactly on every group size.
        for n in [16usize, 32, 64] {
            let a = vec![i8::MIN; n];
            let want = (n as i64) * 128 * 128;
            assert_eq!(idot_unrolled(&a, &a) as i64, want, "unrolled min-extreme n={n}");
            let gb = [&a[..], &a[..], &a[..], &a[..]];
            let p4 = PortableKernel::dot_1x4(&a, gb);
            assert_eq!(p4.map(|x| x as i64), [want; NR], "portable 1x4 min-extreme n={n}");
            #[cfg(target_arch = "x86_64")]
            {
                if super::super::simd_isa() == SimdIsa::Avx2 {
                    assert_eq!(avx2::Avx2Kernel::dot(&a, &a) as i64, want, "avx2 min n={n}");
                    let v4 = avx2::Avx2Kernel::dot_1x4(&a, gb);
                    assert_eq!(v4.map(|x| x as i64), [want; NR], "avx2 1x4 min n={n}");
                    let v8 = avx2::Avx2Kernel::dot_2x4(&a, &a, gb);
                    assert_eq!(v8[0].map(|x| x as i64), [want; NR], "avx2 2x4 r0 min n={n}");
                    assert_eq!(v8[1].map(|x| x as i64), [want; NR], "avx2 2x4 r1 min n={n}");
                }
            }
        }
    }

    #[test]
    fn idot_exact_widens_beyond_the_i32_safe_span() {
        // Adversarial whole-row reduction at the true i8 extreme
        // (i8::MIN² = 16384 — the product the safe-lane bound must be
        // derived from, NOT 127²), one lane past the provable bound. The
        // true sum exceeds i32::MAX, so an unwidened accumulator would
        // wrap; the chunked i64 path must return the exact value, and a
        // full-length safe chunk must stay inside i32 (no debug-build
        // overflow panic).
        let n = IDOT_I32_SAFE_LANES + 1;
        let a: Vec<i8> = vec![i8::MIN; n];
        let want = (n as i64) * 128 * 128;
        assert!(want > i32::MAX as i64, "the case must actually exceed i32");
        assert_eq!(lanes_idot_exact(&a, &a), want);
        // A full safe-length chunk is the worst case lanes_idot may see:
        // it must fit i32 exactly.
        assert!((IDOT_I32_SAFE_LANES as i64) * 128 * 128 <= i32::MAX as i64);
        // And group-sized spans still take the single-chunk fast path.
        assert_eq!(lanes_idot_exact(&a[..64], &a[..64]), 64 * 128 * 128);
    }

    #[test]
    fn simd_kernel_matches_scalar_packed_kernel_bitwise() {
        // Unit-level smoke of the tiled backend (the full parity matrix
        // lives in tests/packed_parity.rs): both explicit plane backends,
        // plus the knob-dispatching entry, agree bit for bit — across row
        // tails (odd m), column tails (n % NR != 0) and K tails.
        let mut rng = Rng::seed(521);
        for kind in QuantKind::ALL {
            for (m, k, n) in [(3, 130, 5), (1, 40, 1), (7, 64, 11)] {
                let a = Matrix::randn(m, k, 1.0, &mut rng);
                let b = Matrix::randn(n, k, 1.0, &mut rng);
                let pa = QuantizedMatrix::quantize(kind, &a, MODE).pack_threads(1);
                let pb = QuantizedMatrix::quantize(kind, &b, MODE).pack_threads(1);
                let scalar = pa.qgemm_bt_packed_threads(&pb, 1);
                let simd = pa.qgemm_bt_simd_threads(&pb, 1);
                let dispatched = pa.qgemm_bt_threads(&pb, 1);
                let bits = |m: &Matrix| m.data.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
                assert_eq!(bits(&scalar), bits(&simd), "{kind} {m}x{k}x{n}");
                assert_eq!(bits(&scalar), bits(&dispatched), "{kind} {m}x{k}x{n} dispatch");
            }
        }
    }
}
