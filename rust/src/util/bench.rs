//! Hand-rolled benchmark harness (the offline image has no `criterion`).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses
//! [`BenchRunner`] for timing (warmup + timed iterations, mean/p50/p99) and
//! [`Table`] for paper-style table output. Results print to stdout so
//! `cargo bench | tee bench_output.txt` records everything.

use std::time::{Duration, Instant};

/// Timing statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elems_per_iter: Option<u64>,
}

impl BenchStats {
    pub fn throughput(&self) -> Option<f64> {
        self.elems_per_iter.map(|e| e as f64 / self.mean.as_secs_f64())
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:7.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:7.2} Melem/s", t / 1e6),
            Some(t) => format!("  {:7.2} Kelem/s", t / 1e3),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p99{}",
            self.name, self.mean, self.p50, self.p99, tp
        )
    }
}

/// Warmup + timed-iteration runner.
pub struct BenchRunner {
    /// Minimum measurement time per case.
    pub min_time: Duration,
    /// Maximum iterations per case (bounds very fast cases).
    pub max_iters: usize,
    pub warmup_iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner {
            min_time: Duration::from_millis(300),
            max_iters: 10_000,
            warmup_iters: 3,
        }
    }
}

impl BenchRunner {
    /// Quick-mode runner for CI-ish runs (HIF4_BENCH_QUICK=1).
    pub fn from_env() -> BenchRunner {
        if std::env::var("HIF4_BENCH_QUICK").is_ok() {
            BenchRunner {
                min_time: Duration::from_millis(50),
                max_iters: 200,
                warmup_iters: 1,
            }
        } else {
            BenchRunner::default()
        }
    }

    /// Time `f` and return stats. `f` must do one unit of work per call.
    pub fn run<F: FnMut()>(&self, name: &str, elems_per_iter: Option<u64>, mut f: F) -> BenchStats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.min_time && samples.len() < self.max_iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let p50 = samples[samples.len() / 2];
        let p99 = samples[(samples.len() * 99 / 100).min(samples.len() - 1)];
        let stats = BenchStats {
            name: name.to_string(),
            iters: samples.len(),
            mean,
            p50,
            p99,
            elems_per_iter,
        };
        println!("{}", stats.report());
        stats
    }
}

/// Paper-style fixed-width table printer.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_measures() {
        let r = BenchRunner {
            min_time: Duration::from_millis(5),
            max_iters: 100,
            warmup_iters: 1,
        };
        let mut x = 0u64;
        let s = r.run("spin", Some(1000), || {
            for i in 0..1000u64 {
                x = x.wrapping_add(i);
            }
        });
        assert!(s.iters > 0);
        assert!(s.mean > Duration::ZERO);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(x > 0 || x == 0); // keep the side effect alive
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "val"]);
        t.row(vec!["a".into(), "1.00".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("=== Demo ==="));
        assert!(s.contains("long-name"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
