//! Deterministic interleaving harness — loom-style schedule exploration
//! without the dependency.
//!
//! Model: each logical "thread" is a scripted sequence of *steps*
//! (closures over shared state). A **schedule** is one merge order of
//! those sequences — `[0, 1, 0, 2, …]` means thread 0's next step, then
//! thread 1's, then thread 0's again. The explorer runs the scripted
//! steps *single-threaded* in schedule order, so every execution is
//! perfectly reproducible: a failing schedule prints as a literal vector
//! that replays the race forever.
//!
//! What this checks — and what it honestly does not: operations
//! interleave at **API granularity** (one step = one call like
//! `try_enqueue` or `alloc`), not at instruction granularity. That is
//! the right level for the invariants DESIGN.md §16 cares about
//! (reserve/rollback accounting, alloc/free/evict bookkeeping): those
//! contracts are about *orderings of completed operations*, and the
//! atomics inside each operation are separately exercised by the real
//! multi-threaded chaos/soak tests. A loom-grade memory-model explorer
//! is out of scope for an offline tree.
//!
//! Exploration is exhaustive when the merge-order count fits the given
//! budget, otherwise a seeded sample (via [`crate::tensor::Rng`], so CI
//! and local runs see the same schedules) that always includes the
//! canonical corner schedules: round-robin and every "thread i runs
//! first, alone" order.

use crate::tensor::Rng;

/// Number of distinct merge orders of sequences with the given lengths
/// (the multinomial coefficient), saturating at `u128::MAX`.
pub fn merge_order_count(counts: &[usize]) -> u128 {
    // total! / prod(counts!) computed incrementally as C(n, k) products
    // to stay in range for every realistic harness size.
    let mut total: u128 = 1;
    let mut placed: u128 = 0;
    for &c in counts {
        for i in 1..=c as u128 {
            placed += 1;
            total = total.saturating_mul(placed) / i;
        }
    }
    total
}

/// All (or a seeded sample of) merge orders for per-thread step counts.
///
/// * exhaustive when [`merge_order_count`] ≤ `limit`;
/// * otherwise `limit` seeded-random schedules plus the corner cases
///   (round-robin, each thread sequentially first), deduplicated.
pub fn interleavings(counts: &[usize], seed: u64, limit: usize) -> Vec<Vec<usize>> {
    let total_steps: usize = counts.iter().sum();
    if total_steps == 0 {
        return vec![Vec::new()];
    }
    if merge_order_count(counts) <= limit as u128 {
        let mut out = Vec::new();
        let mut remaining = counts.to_vec();
        let mut prefix = Vec::with_capacity(total_steps);
        enumerate(&mut remaining, &mut prefix, total_steps, &mut out);
        return out;
    }
    let mut out: Vec<Vec<usize>> = Vec::new();
    // Corner schedules first: round-robin…
    let mut rr = Vec::with_capacity(total_steps);
    let mut left = counts.to_vec();
    while rr.len() < total_steps {
        for (t, l) in left.iter_mut().enumerate() {
            if *l > 0 {
                *l -= 1;
                rr.push(t);
            }
        }
    }
    out.push(rr);
    // …and "thread t first" sequential orders.
    for first in 0..counts.len() {
        let mut seq = Vec::with_capacity(total_steps);
        seq.extend(std::iter::repeat_n(first, counts[first]));
        for (t, &c) in counts.iter().enumerate() {
            if t != first {
                seq.extend(std::iter::repeat_n(t, c));
            }
        }
        out.push(seq);
    }
    let mut rng = Rng::seed(seed);
    while out.len() < limit + 1 + counts.len() {
        let mut left = counts.to_vec();
        let mut sched = Vec::with_capacity(total_steps);
        for _ in 0..total_steps {
            let live: Vec<usize> =
                (0..left.len()).filter(|&t| left[t] > 0).collect();
            let pick = live[rng.below(live.len())];
            left[pick] -= 1;
            sched.push(pick);
        }
        out.push(sched);
    }
    out.sort();
    out.dedup();
    out
}

fn enumerate(
    remaining: &mut [usize],
    prefix: &mut Vec<usize>,
    total: usize,
    out: &mut Vec<Vec<usize>>,
) {
    if prefix.len() == total {
        out.push(prefix.clone());
        return;
    }
    for t in 0..remaining.len() {
        if remaining[t] == 0 {
            continue;
        }
        remaining[t] -= 1;
        prefix.push(t);
        enumerate(remaining, prefix, total, out);
        prefix.pop();
        remaining[t] += 1;
    }
}

/// A scripted thread: a named sequence of steps over shared state `S`.
pub struct Script<S> {
    pub name: &'static str,
    pub steps: Vec<Box<dyn Fn(&mut S)>>,
}

impl<S> Script<S> {
    pub fn new(name: &'static str) -> Script<S> {
        Script { name, steps: Vec::new() }
    }

    /// Append one step. Steps must be re-runnable: the explorer replays
    /// the whole script once per schedule against fresh state.
    pub fn step(mut self, f: impl Fn(&mut S) + 'static) -> Script<S> {
        self.steps.push(Box::new(f));
        self
    }
}

/// Run every schedule of `scripts` against fresh state, checking an
/// invariant after **every step**. Panics (with the replayable schedule)
/// on the first violation — the deterministic analogue of a loom model
/// failure.
///
/// * `mk_state` builds the shared state once per schedule;
/// * `invariant` returns `Err(why)` to fail the exploration;
/// * `seed`/`limit` select the sampled schedules past the exhaustive
///   budget (see [`interleavings`]).
pub fn explore<S>(
    scripts: &[Script<S>],
    mk_state: impl Fn() -> S,
    invariant: impl Fn(&S) -> Result<(), String>,
    seed: u64,
    limit: usize,
) -> usize {
    let counts: Vec<usize> = scripts.iter().map(|s| s.steps.len()).collect();
    let schedules = interleavings(&counts, seed, limit);
    let n = schedules.len();
    for sched in &schedules {
        let mut state = mk_state();
        let mut cursor = vec![0usize; scripts.len()];
        if let Err(why) = invariant(&state) {
            panic!("interleave: invariant failed before any step: {why}");
        }
        for (pos, &t) in sched.iter().enumerate() {
            let step = &scripts[t].steps[cursor[t]];
            step(&mut state);
            cursor[t] += 1;
            if let Err(why) = invariant(&state) {
                panic!(
                    "interleave: invariant failed after step {pos} \
                     ({} step {}) of schedule {sched:?}: {why}",
                    scripts[t].name,
                    cursor[t] - 1,
                );
            }
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_counts_are_multinomial() {
        assert_eq!(merge_order_count(&[1, 1]), 2);
        assert_eq!(merge_order_count(&[2, 2]), 6);
        assert_eq!(merge_order_count(&[3, 3]), 20);
        assert_eq!(merge_order_count(&[2, 2, 2]), 90);
    }

    #[test]
    fn exhaustive_enumeration_is_complete_and_unique() {
        let scheds = interleavings(&[2, 2], 1, 100);
        assert_eq!(scheds.len(), 6);
        let mut uniq = scheds.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 6);
        for s in &scheds {
            assert_eq!(s.iter().filter(|&&t| t == 0).count(), 2);
            assert_eq!(s.iter().filter(|&&t| t == 1).count(), 2);
        }
    }

    #[test]
    fn sampling_is_deterministic_and_includes_corners() {
        let a = interleavings(&[4, 4, 4], 7, 50);
        let b = interleavings(&[4, 4, 4], 7, 50);
        assert_eq!(a, b, "same seed must give the same schedules");
        assert!(a.contains(&vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0, 1, 2]), "round-robin present");
        assert!(a.contains(&vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]), "sequential present");
        assert!(a.contains(&vec![1, 1, 1, 1, 0, 0, 0, 0, 2, 2, 2, 2]), "thread-1-first present");
    }

    #[test]
    fn explore_runs_every_step_in_schedule_order() {
        let scripts = vec![
            Script::<Vec<usize>>::new("a").step(|v| v.push(0)).step(|v| v.push(0)),
            Script::<Vec<usize>>::new("b").step(|v| v.push(1)),
        ];
        let n = explore(&scripts, Vec::new, |_| Ok(()), 1, 100);
        assert_eq!(n, 3, "C(3,1) merge orders of [2,1]");
    }

    #[test]
    #[should_panic(expected = "invariant failed")]
    fn explore_panics_with_the_failing_schedule() {
        let scripts = vec![
            Script::<u32>::new("inc").step(|v| *v += 1).step(|v| *v += 1),
            Script::<u32>::new("dbl").step(|v| *v *= 2),
        ];
        // Fails only under some orders (e.g. dbl after both incs).
        explore(
            &scripts,
            || 0,
            |v| if *v > 3 { Err(format!("v = {v}")) } else { Ok(()) },
            1,
            100,
        );
    }
}
