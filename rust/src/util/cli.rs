//! Minimal CLI argument parser (the offline image has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed getters and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut a = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    a.options.insert(stripped.to_string(), v);
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(arg);
            }
        }
        a
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed getter with a default; panics with a clear message on a
    /// malformed value (CLI misuse should fail loudly).
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{name}={v} is not a valid value: {e:?}")),
        }
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--dim=128", "extra"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("dim"), Some("128"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "42", "--rate=0.5"]);
        assert_eq!(a.get_parse("n", 0usize), 42);
        assert_eq!(a.get_parse("rate", 0f64), 0.5);
        assert_eq!(a.get_parse("missing", 7u32), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    #[should_panic(expected = "not a valid value")]
    fn malformed_typed_value_panics() {
        let a = parse(&["--n", "notanumber"]);
        let _ = a.get_parse("n", 0usize);
    }
}
