//! Mini property-testing framework (the offline image has no `proptest`).
//!
//! Seeded case generation + greedy shrinking on failure. Used for the
//! coordinator/batching invariants and the format-roundtrip properties.
//!
//! ```ignore
//! check(200, seed, gen_vec_f32(64, 10.0), |v| roundtrip_ok(v));
//! ```

use crate::tensor::rng::Rng;

/// A generator produces a case from the RNG; shrink proposes smaller cases.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate simplifications of a failing case (nearest-first).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `cases` random cases of `gen` through `prop`; on failure, shrink
/// greedily and panic with the minimal counterexample.
pub fn check<G, P>(cases: usize, seed: u64, gen: &G, prop: P)
where
    G: Gen,
    P: Fn(&G::Value) -> bool,
{
    let mut rng = Rng::seed(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if prop(&v) {
            continue;
        }
        // Shrink loop: take the first failing simplification, repeat.
        let mut minimal = v;
        'shrinking: loop {
            for cand in gen.shrink(&minimal) {
                if !prop(&cand) {
                    minimal = cand;
                    continue 'shrinking;
                }
            }
            break;
        }
        panic!("property failed at case {case} (seed {seed}); minimal counterexample: {minimal:?}");
    }
}

/// Generator: f32 vectors of fixed length, uniform in [-amp, amp], with a
/// bias toward special values (0, ±amp, tiny) that trip format edge cases.
pub struct VecF32 {
    pub len: usize,
    pub amp: f32,
}

pub fn gen_vec_f32(len: usize, amp: f32) -> VecF32 {
    VecF32 { len, amp }
}

impl Gen for VecF32 {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        (0..self.len)
            .map(|_| match rng.below(10) {
                0 => 0.0,
                1 => self.amp,
                2 => -self.amp,
                3 => self.amp * 1e-6,
                _ => ((rng.uniform() * 2.0 - 1.0) as f32) * self.amp,
            })
            .collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        // Zero one element at a time (keeps the length fixed — block
        // formats need exact group sizes).
        for i in 0..v.len() {
            if v[i] != 0.0 {
                let mut c = v.clone();
                c[i] = 0.0;
                out.push(c);
            }
            if out.len() >= 16 {
                break;
            }
        }
        // Halve all magnitudes.
        if v.iter().any(|x| x.abs() > 1e-30) {
            out.push(v.iter().map(|x| x * 0.5).collect());
        }
        out
    }
}

/// Generator: usize in [lo, hi).
pub struct RangeUsize {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for RangeUsize {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below(self.hi - self.lo)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
        }
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(100, 1, &gen_vec_f32(8, 5.0), |v| v.len() == 8);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        // Fails whenever any element is nonzero; shrinking should drive
        // toward few nonzero entries before panicking.
        check(100, 2, &gen_vec_f32(8, 5.0), |v| v.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn range_gen_in_bounds() {
        let g = RangeUsize { lo: 3, hi: 10 };
        check(200, 3, &g, |v| (3..10).contains(v));
    }

    #[test]
    fn quant_kind_parse_display_roundtrip_property() {
        // The single QuantKind parser round-trips its own spelling and its
        // display name, case-folded arbitrarily; any other string of the
        // same alphabet fails with an error that lists the valid names.
        use crate::formats::QuantKind;
        let idx = RangeUsize { lo: 0, hi: QuantKind::ALL.len() };
        check(200, 11, &idx, |i| {
            let k = QuantKind::ALL[*i];
            let spell = k.spelling();
            // Mixed-case variants of the spelling and the display label
            // must all parse back to the same kind.
            let upper = spell.to_ascii_uppercase();
            let mixed: String = spell
                .chars()
                .enumerate()
                .map(|(j, c)| if j % 2 == 0 { c.to_ascii_uppercase() } else { c })
                .collect();
            spell.parse() == Ok(k)
                && upper.parse() == Ok(k)
                && mixed.parse() == Ok(k)
                && k.name().parse() == Ok(k)
                && k.to_string() == k.name()
        });
        // BFP4's display label parses too (the bfp4 alias).
        assert_eq!("BFP4".parse::<QuantKind>(), Ok(QuantKind::Bfp));
        let err = "int4".parse::<QuantKind>().unwrap_err();
        for k in QuantKind::ALL {
            assert!(err.contains(k.spelling()), "error must list {k}: {err}");
        }
    }

    #[test]
    fn format_soundness_properties() {
        // For every format and any finite input: output is finite, zeros
        // stay zero, signs never flip, magnitudes never overshoot the input
        // peak by more than the scale-rounding slack.
        use crate::formats::{QuantKind, QuantScheme};
        for f in QuantKind::ALL {
            let scheme = QuantScheme::direct(f);
            check(60, 7, &gen_vec_f32(f.group(), 100.0), |v| {
                let q = scheme.quant_dequant_vec(v);
                let amax = v.iter().fold(0f32, |m, x| m.max(x.abs()));
                q.iter().zip(v).all(|(o, i)| {
                    o.is_finite()
                        && (*i != 0.0 || *o == 0.0)
                        && (*o * *i >= 0.0)
                        && o.abs() <= 2.0 * amax + 1e-6
                })
            });
        }
    }
}
