//! Offline-image substrates: CLI parsing, thread pool, mini property-test
//! framework, JSON, deterministic interleaving exploration (the crate
//! cache has no clap/tokio/proptest/criterion/serde/loom).

pub mod bench;
pub mod cli;
pub mod interleave;
pub mod json;
pub mod proptest;
pub mod threadpool;

/// Acquire a mutex, *recovering* from poisoning instead of propagating
/// it. A lock is poisoned when some thread panicked while holding it; for
/// the serving tier that panic is already isolated and accounted for by
/// the worker supervisor, and every value guarded by these locks (reply
/// streams, metrics tags, shared receivers) remains valid mid-update — so
/// the right response is to keep serving, not to cascade the panic into
/// every thread that touches the same lock.
pub fn lock_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::lock_recover;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7, "recovering lock still reads the value");
        *lock_recover(&m) = 9;
        assert_eq!(*lock_recover(&m), 9);
    }
}

