//! Offline-image substrates: CLI parsing, thread pool, mini property-test
//! framework, JSON (the crate cache has no clap/tokio/proptest/criterion/
//! serde).

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod threadpool;
