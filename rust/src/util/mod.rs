//! Offline-image substrates: CLI parsing, thread pool, mini property-test
//! framework (the crate cache has no clap/tokio/proptest/criterion).

pub mod bench;
pub mod cli;
pub mod proptest;
pub mod threadpool;
