//! Fixed-size thread pool and scoped data-parallel helpers (the offline
//! image has no tokio/rayon).
//!
//! Used by the serving worker pool and by every data-parallel hot path:
//! scoped [`parallel_for`] covers plain fork-join index loops, and
//! [`parallel_row_bands`] / [`parallel_row_bands2`] hand each worker a
//! contiguous band of matrix rows to mutate — the backbone of the parallel
//! GEMM/QGEMM/GPTQ kernels. Those kernels keep the per-row floating-point
//! accumulation order independent of the band split, so any thread count
//! produces bit-identical results (asserted by `tests/parallel_parity.rs`).
//!
//! The process-wide default worker count is [`threads`]: the `HIF4_THREADS`
//! environment variable if set, else the machine parallelism; override it
//! programmatically with [`set_threads`] (the CLI exposes `--threads`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Per-thread work floor (in flop-equivalent element-ops) for the parallel
/// entry points: a spawned band must carry at least this much work to
/// amortize its spawn/join cost, so small problems stay serial and
/// mid-sized ones use only as many threads as the work supports.
pub const PAR_MIN_WORK: usize = 1 << 17;

/// Flop-equivalents per element for block *dequantization* (decode +
/// copy — a handful of operations per value). Dequantize call sites weight
/// their element counts by this before [`threads_for`].
pub const DEQUANT_WORK_PER_ELEM: usize = 4;

/// Flop-equivalents per element for the block-quantization codecs
/// (Algorithm 1 runs peak trees, reciprocal scaling and per-element
/// rounding — tens of operations per value, vs ~1 per GEMM element-op).
/// Quantization call sites multiply their element counts by this before
/// [`threads_for`], so a mid-sized weight matrix parallelizes even though
/// its raw element count looks small.
pub const QUANT_WORK_PER_ELEM: usize = 32;

/// Process-wide thread-count override; 0 = not resolved yet.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// The process-wide default worker count for data-parallel kernels:
/// `HIF4_THREADS` if set and positive, else `available_parallelism()`.
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let resolved = std::env::var("HIF4_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|n| *n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    // Cache only if still unset, so a concurrent set_threads() override is
    // never clobbered by a racing default resolution.
    match THREADS.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => resolved,
        Err(current) => current,
    }
}

/// Override the process-wide default worker count (`n >= 1`).
pub fn set_threads(n: usize) {
    assert!(n > 0, "thread count must be positive");
    THREADS.store(n, Ordering::Relaxed);
}

/// Effective thread count for a kernel doing `work` independent element
/// operations: the process default, capped so every thread gets at least
/// [`PAR_MIN_WORK`] element-ops (1 — i.e. serial, no spawns — for
/// anything smaller than two floors' worth).
pub fn threads_for(work: usize) -> usize {
    let cap = work / PAR_MIN_WORK;
    if cap <= 1 {
        1
    } else {
        threads().min(cap)
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming boxed jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("hif4-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Pool sized to the machine (at least 1).
    pub fn default_size() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(job)).expect("workers alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for `i ∈ [0, n)` across `threads` OS threads (scoped; borrows
/// allowed). Chunks indices contiguously.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Split `data` — a row-major `rows × row_len` buffer — into contiguous
/// per-thread row bands and run `f(first_row, band)` on each band across
/// `threads` scoped OS threads (`threads = 1` runs inline with one band
/// covering the whole buffer).
///
/// Rows are never split across bands, so per-row computations (and their
/// floating-point accumulation order) are identical for every thread
/// count — the determinism contract the parallel GEMM paths rely on.
pub fn parallel_row_bands<T, F>(data: &mut [T], row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(row_len > 0 && data.len() % row_len == 0, "buffer must be whole rows");
    let rows = data.len() / row_len;
    let threads = threads.clamp(1, rows);
    if threads == 1 {
        f(0, data);
        return;
    }
    let band_rows = rows.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        for (b, band) in data.chunks_mut(band_rows * row_len).enumerate() {
            s.spawn(move || f(b * band_rows, band));
        }
    });
}

/// Like [`parallel_row_bands`], but bands two buffers with the same row
/// count in lockstep (e.g. a quantized weight matrix plus a per-row loss
/// vector): `f(first_row, band_a, band_b)`.
pub fn parallel_row_bands2<A, B, F>(
    a: &mut [A],
    a_row_len: usize,
    b: &mut [B],
    b_row_len: usize,
    threads: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    if a.is_empty() && b.is_empty() {
        return;
    }
    // Validate the shapes before any early return, so an inconsistent call
    // (e.g. empty A with a nonempty B) panics instead of silently leaving
    // B untouched.
    assert!(a_row_len > 0 && a.len() % a_row_len == 0, "buffer A must be whole rows");
    assert!(b_row_len > 0 && b.len() % b_row_len == 0, "buffer B must be whole rows");
    let rows = a.len() / a_row_len;
    assert_eq!(rows, b.len() / b_row_len, "banded buffers must share the row count");
    let threads = threads.clamp(1, rows);
    if threads == 1 {
        f(0, a, b);
        return;
    }
    let band_rows = rows.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        let bands_a = a.chunks_mut(band_rows * a_row_len);
        let bands_b = b.chunks_mut(band_rows * b_row_len);
        for (i, (band_a, band_b)) in bands_a.zip(bands_b).enumerate() {
            s.spawn(move || f(i * band_rows, band_a, band_b));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for completion.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_every_index() {
        let sum = AtomicU64::new(0);
        parallel_for(1000, 4, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn parallel_for_single_thread_and_empty() {
        let sum = AtomicU64::new(0);
        parallel_for(10, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
        parallel_for(0, 4, |_| panic!("must not run"));
    }

    #[test]
    fn pool_drains_queue_on_shutdown() {
        // Shutdown semantics: dropping the pool closes the channel but the
        // workers keep consuming until the queue is empty — every job that
        // was enqueued before the drop must run exactly once, even the ones
        // still queued behind deliberately slow jobs.
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for i in 0..64 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    if i < 4 {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Pool dropped here with most of the queue still pending.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64, "all queued jobs drained");
    }

    #[test]
    fn row_bands_cover_every_row_once() {
        for threads in [1, 2, 3, 4, 7] {
            let mut data = vec![0u32; 10 * 3];
            parallel_row_bands(&mut data, 3, threads, |first_row, band| {
                for (i, row) in band.chunks_mut(3).enumerate() {
                    for x in row.iter_mut() {
                        *x += 1 + (first_row + i) as u32;
                    }
                }
            });
            for r in 0..10 {
                assert_eq!(&data[r * 3..(r + 1) * 3], [1 + r as u32; 3], "threads={threads}");
            }
        }
    }

    #[test]
    fn row_bands2_stay_in_lockstep() {
        let mut a = vec![0u32; 8 * 4];
        let mut b = vec![0u64; 8];
        parallel_row_bands2(&mut a, 4, &mut b, 1, 3, |first_row, band_a, band_b| {
            for i in 0..band_b.len() {
                let r = (first_row + i) as u32;
                for x in band_a[i * 4..(i + 1) * 4].iter_mut() {
                    *x = r;
                }
                band_b[i] = r as u64 * 10;
            }
        });
        for r in 0..8 {
            assert!(a[r * 4..(r + 1) * 4].iter().all(|x| *x == r as u32));
            assert_eq!(b[r], r as u64 * 10);
        }
    }

    #[test]
    fn thread_knob_round_trips() {
        // threads() resolves to something positive; set_threads overrides.
        assert!(threads() >= 1);
        let prev = threads();
        set_threads(3);
        assert_eq!(threads(), 3);
        assert_eq!(threads_for(PAR_MIN_WORK - 1), 1, "below one floor: serial");
        assert_eq!(threads_for(PAR_MIN_WORK), 1, "one floor's worth: still serial");
        assert_eq!(threads_for(2 * PAR_MIN_WORK), 2, "capped by per-thread floor");
        assert_eq!(threads_for(100 * PAR_MIN_WORK), 3, "capped by process default");
        set_threads(prev);
    }
}
