//! Fixed-size thread pool (the offline image has no tokio/rayon).
//!
//! Used by the serving worker pool and by data-parallel sweeps. Scoped
//! `parallel_for` covers the fork-join pattern the quantization sweeps use.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming boxed jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("hif4-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Pool sized to the machine (at least 1).
    pub fn default_size() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(job)).expect("workers alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for `i ∈ [0, n)` across `threads` OS threads (scoped; borrows
/// allowed). Chunks indices contiguously.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for completion.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_every_index() {
        let sum = AtomicU64::new(0);
        parallel_for(1000, 4, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn parallel_for_single_thread_and_empty() {
        let sum = AtomicU64::new(0);
        parallel_for(10, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
        parallel_for(0, 4, |_| panic!("must not run"));
    }
}
