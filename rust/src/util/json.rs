//! Minimal JSON value + parser + writer (the offline image has no `serde`).
//!
//! Grown for the accuracy battery: `BENCH_accuracy.json` is written from a
//! [`Json`] tree with **insertion-ordered** objects (so reruns diff cleanly
//! line-by-line) and read back by the golden regression test, which walks
//! the numeric leaves via [`Json::flatten_numbers`]. The hand-rolled bench
//! writers (`BENCH_qgemm.json`, …) predate this module and format their
//! strings directly; new machine-read artifacts should go through here so
//! the writer and the test-side parser can never disagree on escaping.
//!
//! Numbers render through Rust's shortest-roundtrip `Display` for `f64`, so
//! `parse(render(x)) == x` bit-for-bit — the property the golden diff's
//! tight tolerances rely on.

use std::fmt::Write as _;

/// A JSON document. Objects preserve insertion order (a `Vec`, not a map):
/// serialization is deterministic and diff-friendly by construction.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object constructor from pairs (insertion order kept).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Member lookup on objects (first match; `None` elsewhere).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(p) => Some(p),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Every numeric leaf as `("a.b.3.c", value)`, depth-first in document
    /// order — the flat view the golden regression diff compares.
    pub fn flatten_numbers(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        self.walk_numbers("", &mut out);
        out
    }

    fn walk_numbers(&self, path: &str, out: &mut Vec<(String, f64)>) {
        let join = |k: &str| if path.is_empty() { k.to_string() } else { format!("{path}.{k}") };
        match self {
            Json::Num(x) => out.push((path.to_string(), *x)),
            Json::Obj(pairs) => {
                for (k, v) in pairs {
                    v.walk_numbers(&join(k), out);
                }
            }
            Json::Arr(items) => {
                for (i, v) in items.iter().enumerate() {
                    v.walk_numbers(&join(&i.to_string()), out);
                }
            }
            _ => {}
        }
    }

    /// Pretty-render with 2-space indentation and a trailing newline — the
    /// one serialization every battery artifact uses (stable across runs
    /// for identical trees, so `git diff` on a golden update shows exactly
    /// the cells that moved).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, s: &mut String, indent: usize) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(s, "{b}");
            }
            Json::Num(x) => write_number(s, *x),
            Json::Str(v) => write_string(s, v),
            Json::Arr(items) if items.is_empty() => s.push_str("[]"),
            Json::Arr(items) => {
                s.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    s.push('\n');
                    s.push_str(&"  ".repeat(indent + 1));
                    v.write(s, indent + 1);
                }
                s.push('\n');
                s.push_str(&"  ".repeat(indent));
                s.push(']');
            }
            Json::Obj(pairs) if pairs.is_empty() => s.push_str("{}"),
            Json::Obj(pairs) => {
                s.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    s.push('\n');
                    s.push_str(&"  ".repeat(indent + 1));
                    write_string(s, k);
                    s.push_str(": ");
                    v.write(s, indent + 1);
                }
                s.push('\n');
                s.push_str(&"  ".repeat(indent));
                s.push('}');
            }
        }
    }
}

fn write_number(s: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no Inf/NaN; the battery treats them as data bugs but the
        // writer must still emit *valid* JSON (the golden diff then fails
        // on the null, loudly).
        s.push_str("null");
    } else if x == 0.0 && x.is_sign_negative() {
        // `as i64` would drop the sign bit; "-0" parses back to -0.0.
        s.push_str("-0");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(s, "{}", x as i64);
    } else {
        // Shortest round-trip representation (Rust's float Display).
        let _ = write!(s, "{x}");
    }
}

fn write_string(s: &mut String, v: &str) {
    s.push('"');
    for c in v.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(s, "\\u{:04x}", c as u32);
            }
            c => s.push(c),
        }
    }
    s.push('"');
}

/// Parse a JSON document; errors carry the byte offset of the failure.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?} at {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| "unterminated string".to_string())?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| "bad escape".to_string())?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| "short \\u escape".to_string())?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.pos += 4;
                            // Surrogate pairs are not needed for battery keys;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                _ => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|e| format!("bad utf8 at {start}: {e}"))?;
                    let ch = rest.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    out.push(ch);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let doc = Json::obj(vec![
            ("name", Json::str("battery")),
            ("quick", Json::Bool(true)),
            ("cells", Json::arr([Json::num(1.0), Json::num(56.25), Json::num(-0.125)])),
            ("nested", Json::obj(vec![("ppl", Json::num(17.25)), ("none", Json::Null)])),
            ("escaped", Json::str("a\"b\\c\nd\ttab")),
        ]);
        let text = doc.render();
        let back = parse(&text).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn numbers_roundtrip_bit_exact() {
        // Shortest-roundtrip Display: parse(render(x)) == x for awkward
        // values (the golden diff's exact-pin tolerance depends on this).
        for x in [
            1.0 / 3.0,
            66.666_666_666_666_67,
            1e-9,
            123456789.0,
            0.1 + 0.2,
            f64::MIN_POSITIVE,
            -0.0,
        ] {
            let text = Json::num(x).render();
            let y = parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(x.to_bits(), y.to_bits(), "{x} -> {text}");
        }
    }

    #[test]
    fn object_order_preserved() {
        let doc = Json::obj(vec![("z", Json::num(1.0)), ("a", Json::num(2.0))]);
        let text = doc.render();
        assert!(text.find("\"z\"").unwrap() < text.find("\"a\"").unwrap());
        // And the parser keeps it.
        let back = parse(&text).unwrap();
        assert_eq!(back.as_obj().unwrap()[0].0, "z");
    }

    #[test]
    fn flatten_paths() {
        let doc = Json::obj(vec![
            ("a", Json::obj(vec![("b", Json::num(1.0))])),
            ("arr", Json::arr([Json::num(2.0), Json::str("skip"), Json::num(3.0)])),
        ]);
        let flat = doc.flatten_numbers();
        assert_eq!(
            flat,
            vec![("a.b".to_string(), 1.0), ("arr.0".to_string(), 2.0), ("arr.2".to_string(), 3.0)]
        );
    }

    #[test]
    fn parse_errors_carry_position() {
        assert!(parse("{\"a\": }").unwrap_err().contains("byte"));
        assert!(parse("[1, 2").unwrap_err().contains("expected"));
        assert!(parse("{} junk").unwrap_err().contains("trailing"));
    }

    #[test]
    fn integers_render_without_exponent() {
        assert_eq!(Json::num(3.0).render(), "3\n");
        assert_eq!(Json::num(56.25).render(), "56.25\n");
    }
}
