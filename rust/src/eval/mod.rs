//! Synthetic evaluation harness mirroring the paper's benchmark suites.

pub mod harness;
pub mod tasks;
