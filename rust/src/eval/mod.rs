//! Synthetic evaluation harness mirroring the paper's benchmark suites:
//! task generators, likelihood scoring, held-out perplexity, and the
//! format × mode × model × task accuracy battery.

pub mod battery;
pub mod harness;
pub mod ppl;
pub mod tasks;
