//! Synthetic language + benchmark generators (DESIGN.md §4 substitution for
//! ARC-C/E, BoolQ, HellaSwag, Lambada, PiQA, WinoGrande, MMLU — plus the
//! Gsm8K / Math500 / CMMLU analogues Table V adds).
//!
//! The corpus is a probabilistic grammar with learnable regularities a tiny
//! transformer can acquire: determiner–noun–verb *class agreement*, a
//! long-range *copy/coreference* rule, digit *successor* and *skip-counting*
//! runs, and a low-frequency second "language" domain. Each benchmark
//! isolates one phenomenon in the paper's task *shape* (2-way / 4-way
//! multiple choice, cloze, yes/no), so PTQ accuracy drops measure how
//! quantization erodes the trained model's likelihood margins.

use crate::tensor::Rng;

/// Vocabulary layout (total 320).
pub const VOCAB: usize = 320;
pub const SEP: usize = 1;
pub const TRIG: usize = 300; // coreference trigger: "the aforementioned"
const DIGIT0: usize = 2; // D0..D9 = 2..=11
const DET_A: (usize, usize) = (12, 16);
const DET_B: (usize, usize) = (16, 20);
const NOUN_A: (usize, usize) = (20, 50);
const NOUN_B: (usize, usize) = (50, 80);
const VERB_A: (usize, usize) = (80, 110);
const VERB_B: (usize, usize) = (110, 140);
const ADJ: (usize, usize) = (140, 160);
const NAME: (usize, usize) = (160, 200);
// Domain 2 ("CMMLU" analogue): disjoint vocabulary, 10× rarer in training.
const DET2_A: (usize, usize) = (200, 204);
const DET2_B: (usize, usize) = (204, 208);
const NOUN2_A: (usize, usize) = (208, 224);
const NOUN2_B: (usize, usize) = (224, 240);
const VERB2_A: (usize, usize) = (240, 270);
const VERB2_B: (usize, usize) = (270, 300);

fn pick(rng: &mut Rng, range: (usize, usize)) -> usize {
    range.0 + rng.below(range.1 - range.0)
}

/// One corpus sentence (ends with SEP).
pub fn sentence(rng: &mut Rng) -> Vec<usize> {
    match rng.below(100) {
        // 50%: domain-1 agreement sentence.
        0..=49 => agreement_sentence(rng, false),
        // 10%: domain-2 agreement sentence.
        50..=59 => agreement_sentence(rng, true),
        // 15%: copy / coreference.
        60..=74 => copy_sentence(rng),
        // 15%: digit successor run.
        75..=89 => digit_run(rng, 1),
        // 10%: skip-2 run.
        _ => digit_run(rng, 2),
    }
}

/// DET_c NOUN_c [ADJ] VERB_c [NOUN_any] SEP with class agreement.
fn agreement_sentence(rng: &mut Rng, domain2: bool) -> Vec<usize> {
    let class_a = rng.below(2) == 0;
    let (det, noun, verb) = ranges(class_a, domain2);
    let mut s = vec![pick(rng, det), pick(rng, noun)];
    if !domain2 && rng.below(2) == 0 {
        s.push(pick(rng, ADJ));
    }
    s.push(pick(rng, verb));
    if rng.below(2) == 0 {
        let (_, obj_noun, _) = ranges(rng.below(2) == 0, domain2);
        s.push(pick(rng, obj_noun));
    }
    s.push(SEP);
    s
}

fn ranges(class_a: bool, domain2: bool) -> ((usize, usize), (usize, usize), (usize, usize)) {
    match (class_a, domain2) {
        (true, false) => (DET_A, NOUN_A, VERB_A),
        (false, false) => (DET_B, NOUN_B, VERB_B),
        (true, true) => (DET2_A, NOUN2_A, VERB2_A),
        (false, true) => (DET2_B, NOUN2_B, VERB2_B),
    }
}

/// NAME_x (filler sentence) TRIG NAME_x SEP — the name repeats after TRIG.
fn copy_sentence(rng: &mut Rng) -> Vec<usize> {
    let x = pick(rng, NAME);
    let mut s = vec![x];
    s.extend(agreement_sentence(rng, false));
    s.pop(); // drop inner SEP
    s.push(TRIG);
    s.push(x);
    s.push(SEP);
    s
}

/// D_i D_{i+step} D_{i+2·step} D_{i+3·step} SEP.
fn digit_run(rng: &mut Rng, step: usize) -> Vec<usize> {
    let max_start = 9 - 3 * step;
    let i = rng.below(max_start + 1);
    (0..4).map(|k| DIGIT0 + i + k * step).chain([SEP]).collect()
}

/// Sample a training sequence of ~`len` tokens (whole sentences).
pub fn training_sequence(rng: &mut Rng, len: usize) -> Vec<usize> {
    let mut s = Vec::with_capacity(len + 8);
    while s.len() < len {
        s.extend(sentence(rng));
    }
    s.truncate(len);
    s
}

/// A multiple-choice item: context, candidate continuations, gold index.
#[derive(Debug, Clone)]
pub struct Item {
    pub context: Vec<usize>,
    pub choices: Vec<Vec<usize>>,
    pub gold: usize,
}

/// The benchmark suite: a name + item generator per task shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    /// ARC-C analogue: 4-way verb choice, hard distractors (wrong-class
    /// verbs — same surface distribution).
    AgreeHard,
    /// ARC-E analogue: 4-way, easy distractors (non-verbs).
    AgreeEasy,
    /// BoolQ analogue: 2-way correct-verb vs wrong-class-verb.
    YesNo,
    /// HellaSwag analogue: 4-way multi-token continuation.
    Continuation,
    /// Lambada analogue: cloze — predict the copied name (4 candidates).
    LastWord,
    /// PiQA analogue: 2-way noun-class consistency after a determiner.
    Physical,
    /// WinoGrande analogue: 2-way coreference (which name follows TRIG).
    Coref,
    /// MMLU analogue: mixed 4-way over all phenomena.
    MultiDomain,
    /// Gsm8K analogue: digit successor arithmetic, 4-way.
    Arith,
    /// Math500 analogue: skip-2 counting (harder pattern), 4-way.
    SkipCount,
    /// CMMLU analogue: agreement in the rare second domain, 4-way.
    Domain2,
}

impl Task {
    pub fn name(self) -> &'static str {
        match self {
            Task::AgreeHard => "ARC-C*",
            Task::AgreeEasy => "ARC-E*",
            Task::YesNo => "BoolQ*",
            Task::Continuation => "HellaS*",
            Task::LastWord => "LamOp*",
            Task::Physical => "Piqa*",
            Task::Coref => "WinoG*",
            Task::MultiDomain => "MMLU*",
            Task::Arith => "Gsm8K*",
            Task::SkipCount => "Math500*",
            Task::Domain2 => "CMMLU*",
        }
    }

    /// The Table III suite (8 benchmarks).
    pub fn small_suite() -> Vec<Task> {
        vec![
            Task::AgreeHard,
            Task::AgreeEasy,
            Task::YesNo,
            Task::Continuation,
            Task::LastWord,
            Task::Physical,
            Task::Coref,
            Task::MultiDomain,
        ]
    }

    /// The Table V suite (10 benchmarks; the paper swaps LamOp for Gsm8K/
    /// Math500/CMMLU).
    pub fn large_suite() -> Vec<Task> {
        vec![
            Task::AgreeHard,
            Task::AgreeEasy,
            Task::YesNo,
            Task::Continuation,
            Task::Physical,
            Task::Coref,
            Task::Arith,
            Task::MultiDomain,
            Task::SkipCount,
            Task::Domain2,
        ]
    }

    /// Generate one item.
    pub fn item(self, rng: &mut Rng) -> Item {
        match self {
            Task::AgreeHard => {
                let class_a = rng.below(2) == 0;
                let (det, noun, verb) = ranges(class_a, false);
                let (_, _, wrong_verb) = ranges(!class_a, false);
                let context = vec![pick(rng, det), pick(rng, noun), pick(rng, ADJ)];
                mc4(rng, context, verb, wrong_verb)
            }
            Task::AgreeEasy => {
                let class_a = rng.below(2) == 0;
                let (det, noun, verb) = ranges(class_a, false);
                let context = vec![pick(rng, det), pick(rng, noun)];
                // Easy distractors: determiners and sentence-initial names
                // never follow a noun in the grammar (vs AgreeHard whose
                // distractors are verbs of the wrong class).
                let gold = rng.below(4);
                let choices = (0..4)
                    .map(|i| {
                        if i == gold {
                            vec![pick(rng, verb)]
                        } else {
                            vec![pick(rng, if i % 2 == 0 { DET_B } else { DET_A })]
                        }
                    })
                    .collect();
                Item { context, choices, gold }
            }
            Task::YesNo => {
                let class_a = rng.below(2) == 0;
                let (det, noun, verb) = ranges(class_a, false);
                let (_, _, wrong_verb) = ranges(!class_a, false);
                let context = vec![pick(rng, det), pick(rng, noun)];
                let gold = rng.below(2);
                let choices = (0..2)
                    .map(|i| vec![pick(rng, if i == gold { verb } else { wrong_verb })])
                    .collect();
                Item { context, choices, gold }
            }
            Task::Continuation => {
                let class_a = rng.below(2) == 0;
                let (det, noun, verb) = ranges(class_a, false);
                let (wdet, wnoun, wverb) = ranges(!class_a, false);
                let context = vec![pick(rng, det), pick(rng, noun)];
                let gold = rng.below(4);
                let choices = (0..4)
                    .map(|i| {
                        if i == gold {
                            // consistent: VERB_c NOUN SEP
                            vec![pick(rng, verb), pick(rng, wnoun), SEP]
                        } else {
                            // inconsistent continuation
                            vec![pick(rng, wverb), pick(rng, wdet), SEP]
                        }
                    })
                    .collect();
                Item { context, choices, gold }
            }
            Task::LastWord => {
                let x = pick(rng, NAME);
                let mut context = vec![x];
                context.extend(agreement_sentence(rng, false));
                context.pop();
                context.push(TRIG);
                let gold = rng.below(4);
                let choices = (0..4)
                    .map(|i| {
                        if i == gold {
                            vec![x]
                        } else {
                            // distinct distractor names
                            loop {
                                let y = pick(rng, NAME);
                                if y != x {
                                    break vec![y];
                                }
                            }
                        }
                    })
                    .collect();
                Item { context, choices, gold }
            }
            Task::Physical => {
                let class_a = rng.below(2) == 0;
                let (det, noun, _) = ranges(class_a, false);
                let (_, wrong_noun, _) = ranges(!class_a, false);
                let context = vec![pick(rng, det)];
                let gold = rng.below(2);
                let choices = (0..2)
                    .map(|i| vec![pick(rng, if i == gold { noun } else { wrong_noun })])
                    .collect();
                Item { context, choices, gold }
            }
            Task::Coref => {
                let x = pick(rng, NAME);
                let y = loop {
                    let y = pick(rng, NAME);
                    if y != x {
                        break y;
                    }
                };
                // Corpus rule: the *first* name repeats after TRIG.
                let mut context = vec![x];
                context.extend(agreement_sentence(rng, false));
                context.pop();
                context.push(y); // distractor mention (unseen pattern noise)
                context.push(TRIG);
                let gold = rng.below(2);
                let choices =
                    (0..2).map(|i| vec![if i == gold { x } else { y }]).collect();
                Item { context, choices, gold }
            }
            Task::MultiDomain => {
                // Mixture of the other 4-way generators.
                match rng.below(3) {
                    0 => Task::AgreeHard.item(rng),
                    1 => Task::Continuation.item(rng),
                    _ => Task::Arith.item(rng),
                }
            }
            Task::Arith => {
                let i = rng.below(7);
                let context = vec![DIGIT0 + i, DIGIT0 + i + 1, DIGIT0 + i + 2];
                let correct = DIGIT0 + i + 3;
                digit_mc(rng, context, correct)
            }
            Task::SkipCount => {
                let i = rng.below(4);
                let context = vec![DIGIT0 + i, DIGIT0 + i + 2, DIGIT0 + i + 4];
                let correct = DIGIT0 + i + 6;
                digit_mc(rng, context, correct)
            }
            Task::Domain2 => {
                let class_a = rng.below(2) == 0;
                let (det, noun, verb) = ranges(class_a, true);
                let (_, _, wrong_verb) = ranges(!class_a, true);
                let context = vec![pick(rng, det), pick(rng, noun)];
                mc4(rng, context, verb, wrong_verb)
            }
        }
    }
}

/// 4-way MC: one token from `good`, three from `bad`.
fn mc4(rng: &mut Rng, context: Vec<usize>, good: (usize, usize), bad: (usize, usize)) -> Item {
    let gold = rng.below(4);
    let choices = (0..4)
        .map(|i| vec![pick(rng, if i == gold { good } else { bad })])
        .collect();
    Item { context, choices, gold }
}

/// 4-way MC over digits: correct successor vs other digits.
fn digit_mc(rng: &mut Rng, context: Vec<usize>, correct: usize) -> Item {
    let gold = rng.below(4);
    let choices = (0..4)
        .map(|i| {
            if i == gold {
                vec![correct]
            } else {
                loop {
                    let d = DIGIT0 + rng.below(10);
                    if d != correct {
                        break vec![d];
                    }
                }
            }
        })
        .collect();
    Item { context, choices, gold }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentences_stay_in_vocab() {
        let mut rng = Rng::seed(1);
        for _ in 0..500 {
            for t in sentence(&mut rng) {
                assert!(t < VOCAB);
            }
        }
    }

    #[test]
    fn training_sequence_length() {
        let mut rng = Rng::seed(2);
        let s = training_sequence(&mut rng, 40);
        assert_eq!(s.len(), 40);
    }

    #[test]
    fn items_well_formed() {
        let mut rng = Rng::seed(3);
        for task in Task::small_suite().into_iter().chain(Task::large_suite()) {
            for _ in 0..50 {
                let item = task.item(&mut rng);
                assert!(item.gold < item.choices.len(), "{}", task.name());
                assert!(!item.context.is_empty());
                for ch in &item.choices {
                    assert!(!ch.is_empty());
                    for t in ch.iter().chain(&item.context) {
                        assert!(*t < VOCAB);
                    }
                }
                // Gold choice differs from every distractor.
                for (i, ch) in item.choices.iter().enumerate() {
                    if i != item.gold {
                        assert_ne!(ch, &item.choices[item.gold], "{}", task.name());
                    }
                }
            }
        }
    }

    #[test]
    fn copy_rule_present_in_corpus() {
        // TRIG must be followed by the first token of its sentence.
        let mut rng = Rng::seed(4);
        let mut seen = 0;
        for _ in 0..300 {
            let s = sentence(&mut rng);
            if let Some(p) = s.iter().position(|t| *t == TRIG) {
                assert_eq!(s[p + 1], s[0], "copy rule violated");
                seen += 1;
            }
        }
        assert!(seen > 10, "copy sentences should appear");
    }

    #[test]
    fn gold_answer_uniform() {
        // No positional bias in gold indices.
        let mut rng = Rng::seed(5);
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[Task::AgreeHard.item(&mut rng).gold] += 1;
        }
        for c in counts {
            assert!((c as f64 / 2000.0 - 0.25).abs() < 0.05);
        }
    }
}
