//! Perplexity on a deterministic seeded held-out corpus — the battery's
//! second metric next to task accuracy (Wikitext-style ppl in the
//! comparison papers; here the held-out text is the synthetic grammar).
//!
//! The held-out corpus is generated **up front** from one seeded RNG, then
//! scored sequence-by-sequence in corpus order; the forward batch size
//! only groups sequences per call. Combined with the crate's per-row
//! determinism contract (every kernel is bit-identical for any thread
//! count and any batch packing), perplexity is a pure function of
//! `(model, policy, PplConfig)` — the property `ppl_invariants` pins.

use super::harness::log_softmax_at;
use crate::eval::tasks;
use crate::model::transformer::{QuantPolicy, Transformer};

/// Held-out corpus + batching knobs. `seed` picks the corpus (disjoint by
/// convention from the training stream's `seed ^ 0xC0FFEE` mixing and the
/// eval-task seeds); `batch` is pure execution shape.
#[derive(Debug, Clone)]
pub struct PplConfig {
    pub n_seqs: usize,
    pub seq_len: usize,
    pub seed: u64,
    pub batch: usize,
}

impl Default for PplConfig {
    fn default() -> Self {
        PplConfig { n_seqs: 24, seq_len: 32, seed: 0x9E1D0, batch: 8 }
    }
}

/// The deterministic held-out corpus: `n_seqs` sequences of `seq_len`
/// tokens, all drawn from one seeded RNG in order (so the corpus is a pure
/// function of the config, independent of how it is later batched).
pub fn held_out_corpus(cfg: &PplConfig) -> Vec<Vec<usize>> {
    let mut rng = crate::tensor::Rng::seed(cfg.seed);
    (0..cfg.n_seqs).map(|_| tasks::training_sequence(&mut rng, cfg.seq_len)).collect()
}

/// Corpus perplexity: exp of the mean next-token negative log-likelihood
/// over every position of every held-out sequence (positions 1.., since
/// position 0 has no context). Accumulation runs in corpus order with f64
/// addition, so the result is bit-identical for any `batch`.
pub fn perplexity(model: &Transformer, policy: Option<&QuantPolicy>, cfg: &PplConfig) -> f64 {
    let seqs = held_out_corpus(cfg);
    let mut nll = 0f64;
    let mut count = 0usize;
    for chunk in seqs.chunks(cfg.batch.max(1)) {
        let logits = model.forward(chunk, policy, None, None);
        let mut row_base = 0usize;
        for seq in chunk {
            for pos in 1..seq.len() {
                nll -= log_softmax_at(&logits, row_base + pos - 1, seq[pos]);
                count += 1;
            }
            row_base += seq.len();
        }
    }
    (nll / count as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::Transformer;
    use crate::model::zoo;
    use crate::util::proptest::{check, RangeUsize};
    use crate::util::threadpool;

    #[test]
    fn corpus_is_deterministic_and_disjoint_from_other_seeds() {
        let cfg = PplConfig::default();
        assert_eq!(held_out_corpus(&cfg), held_out_corpus(&cfg));
        let other = PplConfig { seed: cfg.seed + 1, ..cfg.clone() };
        assert_ne!(held_out_corpus(&cfg), held_out_corpus(&other));
        for s in held_out_corpus(&cfg) {
            assert_eq!(s.len(), cfg.seq_len);
            assert!(s.iter().all(|t| *t < tasks::VOCAB));
        }
    }

    #[test]
    fn ppl_invariants() {
        // Property (packed_parity conventions): for any seeded zoo model,
        // any batch size and any thread count, perplexity is strictly
        // finite and bit-identical to the single-sequence serial
        // reference. This is the determinism contract the golden accuracy
        // file relies on, stated as a property instead of a fixture.
        let models: Vec<Transformer> = zoo::keyed()
            .into_iter()
            .map(|(key, cfg)| Transformer::init(cfg, zoo::train_seed(key)))
            .collect();
        let base = PplConfig { n_seqs: 3, seq_len: 16, seed: 7, batch: 1 };
        let reference: Vec<f64> = models.iter().map(|m| perplexity(m, None, &base)).collect();
        for p in &reference {
            assert!(p.is_finite() && *p > 1.0, "reference ppl {p}");
        }
        let prev_threads = threadpool::threads();
        // Case space: model × batch ∈ [1,6] × threads ∈ [1,4], sampled.
        let gen = RangeUsize { lo: 0, hi: models.len() * 6 * 4 };
        check(24, 0xBA7C4, &gen, |case| {
            let case = *case;
            let mi = case % models.len();
            let batch = 1 + (case / models.len()) % 6;
            let threads = 1 + (case / (models.len() * 6)) % 4;
            threadpool::set_threads(threads);
            let p = perplexity(&models[mi], None, &PplConfig { batch, ..base.clone() });
            threadpool::set_threads(prev_threads);
            p.to_bits() == reference[mi].to_bits()
        });
    }

    #[test]
    fn quantized_policy_moves_ppl_but_keeps_it_finite() {
        use crate::formats::{QuantKind, QuantScheme};
        use crate::model::transformer::QuantPolicy;
        let model = Transformer::init(zoo::llama2_tiny(), 1);
        let cfg = PplConfig { n_seqs: 2, seq_len: 16, seed: 5, batch: 2 };
        let base = perplexity(&model, None, &cfg);
        let policy =
            QuantPolicy { act: Some(QuantScheme::direct(QuantKind::HiF4)), kv: None };
        let quant = perplexity(&model, Some(&policy), &cfg);
        assert!(base.is_finite() && quant.is_finite());
        assert_ne!(base.to_bits(), quant.to_bits(), "activation quant must do something");
    }
}
