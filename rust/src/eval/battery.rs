//! The comparative accuracy battery (ROADMAP O4): one matrix harness
//! sweeping **format × quant mode × zoo model × task**, with corpus
//! perplexity per cell and a per-layer sensitivity sweep, serialized to a
//! schema-versioned JSON document.
//!
//! Axes:
//! * **format** — any subset of [`QuantKind::ALL`] (+ the BF16 baseline
//!   row every drop subtracts);
//! * **mode** — [`QuantMode`]: direct RTN, RTN+PTS, GPTQ, plus optional
//!   real fixed-point rows ([`QuantType::Packed`]) that run the packed
//!   QGEMM so CI exercises every kernel backend through the battery;
//! * **model** — zoo keys ([`zoo::keyed`]), each trained once per battery
//!   on its deterministic [`zoo::train_seed`];
//! * **task** — the synthetic benchmark suite, scored by the harness's
//!   length-normalized likelihood rule, plus held-out perplexity
//!   ([`super::ppl`]).
//!
//! Everything is deterministic end to end (seeded training, seeded eval
//! items, seeded held-out corpus, bit-identical kernels for any thread
//! count/backend), so the quick matrix diffs against a checked-in golden
//! file with tight tolerances — `tests/accuracy_battery.rs` — and a
//! format/kernel regression that preserves parity but moves accuracy
//! cannot ship silently. Entry points: `hif4 eval --battery` and
//! `benches/accuracy_battery.rs` (both write `BENCH_accuracy.json`).

use super::harness::{evaluate, EvalRow};
use super::ppl::{perplexity, PplConfig};
use super::tasks::Task;
use crate::formats::{QuantKind, QuantScheme};
use crate::model::config::LayerKind;
use crate::model::transformer::Transformer;
use crate::model::zoo;
use crate::quant::experiment::{
    quantize_model, train_model, ExperimentConfig, QuantMode, QuantType,
};
use crate::util::json::Json;

/// Layer classes of the sensitivity sweep: quantize exactly one class at a
/// time (weight-only) and report the accuracy delta per class — the
/// per-layer analysis showing *where* a format's error hurts (and why the
/// paper's policy leaves embeddings/LM head in high precision).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerClass {
    /// Attention projections (q/k/v/o and the MLA latent down-projection).
    Attn,
    /// FFN linears, including MoE expert weights (never the gate).
    Mlp,
    /// The token embedding table — a class the paper's policy *excludes*;
    /// the sweep quantifies what that exclusion buys.
    Embed,
}

impl LayerClass {
    pub const ALL: [LayerClass; 3] = [LayerClass::Attn, LayerClass::Mlp, LayerClass::Embed];

    /// Stable JSON key.
    pub fn key(self) -> &'static str {
        match self {
            LayerClass::Attn => "attn",
            LayerClass::Mlp => "mlp",
            LayerClass::Embed => "embed",
        }
    }
}

/// Weight-only quantization of exactly one layer class, leaving everything
/// else (and all activations) in f32 — isolates one class's contribution
/// to the total drop.
pub fn quantize_layer_class(
    model: &Transformer,
    class: LayerClass,
    scheme: &QuantScheme,
) -> Transformer {
    let mut qm = model.clone();
    match class {
        LayerClass::Attn => qm.visit_linears_mut(&mut |lin| {
            if lin.kind == LayerKind::AttnLinear {
                lin.w.data = scheme.quant_dequant_rows(&lin.w.data, lin.w.cols);
            }
        }),
        LayerClass::Mlp => qm.visit_linears_mut(&mut |lin| {
            if matches!(lin.kind, LayerKind::FfnLinear | LayerKind::MoeExpert) {
                lin.w.data = scheme.quant_dequant_rows(&lin.w.data, lin.w.cols);
            }
        }),
        LayerClass::Embed => {
            let cols = qm.w.embed.cols;
            qm.w.embed.data = scheme.quant_dequant_rows(&qm.w.embed.data, cols);
        }
    }
    qm
}

/// The full battery configuration. [`BatteryConfig::quick`] is the CI /
/// golden-file subset; [`BatteryConfig::full`] is the paper-scale matrix
/// behind `hif4 eval --battery` and the release bench.
#[derive(Debug, Clone)]
pub struct BatteryConfig {
    pub quick: bool,
    /// Zoo keys ([`zoo::keyed`] spellings).
    pub models: Vec<String>,
    /// The format axis (every entry crosses every mode).
    pub formats: Vec<QuantKind>,
    /// The mode axis.
    pub modes: Vec<QuantMode>,
    /// Extra real fixed-point rows (packed QGEMM execution), so the
    /// battery exercises the kernel backend CI matrixes over.
    pub fixed_formats: Vec<QuantKind>,
    pub tasks: Vec<Task>,
    pub xcfg: ExperimentConfig,
    pub ppl: PplConfig,
    /// Formats swept per layer class (weight-only).
    pub sensitivity_formats: Vec<QuantKind>,
}

impl BatteryConfig {
    /// The CI quick matrix: 3 architecture-diverse models (MHA; GQA with
    /// the outlier widening that crashes NVFP4; MLA+MoE) × {HiF4, NVFP4}
    /// × {direct, pts, gptq} + one fixed-point row, 3 tasks, 1 eval seed.
    /// Small enough for a debug-mode `cargo test -q`, rich enough that a
    /// format, GPTQ, kernel or eval regression moves at least one cell.
    pub fn quick() -> BatteryConfig {
        BatteryConfig {
            quick: true,
            models: ["llama2", "mistral", "deepseek"].map(String::from).to_vec(),
            formats: vec![QuantKind::HiF4, QuantKind::Nvfp4],
            modes: vec![QuantMode::Direct, QuantMode::Pts, QuantMode::Gptq],
            fixed_formats: vec![QuantKind::HiF4],
            tasks: vec![Task::AgreeEasy, Task::YesNo, Task::Arith],
            xcfg: ExperimentConfig {
                train_steps: 50,
                eval_items: 16,
                eval_seeds: vec![1],
                calib_rows: 64,
                ..ExperimentConfig::default()
            },
            ppl: PplConfig { n_seqs: 4, seq_len: 32, seed: 0x9E1D0, batch: 4 },
            sensitivity_formats: vec![QuantKind::HiF4],
        }
    }

    /// The paper-scale matrix: every zoo model × all five formats × all
    /// three quant modes (+ BF16 baseline + HiF4/NVFP4 fixed-point rows),
    /// the 11-task union suite, 3 eval seeds, default training budget.
    pub fn full() -> BatteryConfig {
        BatteryConfig {
            quick: false,
            models: zoo::keyed().into_iter().map(|(k, _)| k.to_string()).collect(),
            formats: QuantKind::ALL.to_vec(),
            modes: vec![QuantMode::Direct, QuantMode::Pts, QuantMode::Gptq],
            fixed_formats: vec![QuantKind::HiF4, QuantKind::Nvfp4],
            tasks: union_suite(),
            xcfg: ExperimentConfig::default(),
            ppl: PplConfig::default(),
            sensitivity_formats: vec![QuantKind::HiF4, QuantKind::Nvfp4],
        }
    }

    /// The quantized rows of one model block, in reporting order.
    pub fn quant_types(&self) -> Vec<QuantType> {
        let mut types = Vec::new();
        for m in &self.modes {
            for f in &self.formats {
                types.push(m.apply(*f));
            }
        }
        for f in &self.fixed_formats {
            types.push(QuantType::Packed(*f));
        }
        types
    }
}

/// The 11-task union of the Table III and Table V suites, in Table III
/// order with the Table V additions appended.
pub fn union_suite() -> Vec<Task> {
    let mut suite = Task::small_suite();
    for t in Task::large_suite() {
        if !suite.contains(&t) {
            suite.push(t);
        }
    }
    suite
}

/// Run the battery, returning the schema-versioned JSON document (see
/// DESIGN.md §12 for the schema and tolerance policy).
pub fn run(cfg: &BatteryConfig) -> Json {
    let mut models_json = Vec::new();
    for key in &cfg.models {
        let mcfg = zoo::by_key(key)
            .unwrap_or_else(|| panic!("unknown zoo model key {key:?} (see zoo::keyed)"));
        let seed = zoo::train_seed(key);
        let t0 = std::time::Instant::now();
        let (model, losses) = train_model(&mcfg, &cfg.xcfg, seed);

        // BF16 baseline row first, then the quantized matrix.
        let mut rows: Vec<(QuantType, EvalRow, f64)> = Vec::new();
        for qt in std::iter::once(QuantType::Bf16).chain(cfg.quant_types()) {
            let (qm, policy) = quantize_model(&model, qt, &cfg.xcfg);
            let row = evaluate(
                &qm,
                &qt.label(),
                &cfg.tasks,
                cfg.xcfg.eval_items,
                &cfg.xcfg.eval_seeds,
                policy.as_ref(),
            );
            let ppl = perplexity(&qm, policy.as_ref(), &cfg.ppl);
            rows.push((qt, row, ppl));
        }
        let (_, base_row, base_ppl) = &rows[0];
        let base_mean = base_row.mean;
        let base_ppl = *base_ppl;

        let rows_json: Vec<Json> = rows
            .iter()
            .enumerate()
            .map(|(i, (qt, row, ppl))| {
                let base = if i == 0 { None } else { Some((base_mean, base_ppl)) };
                row_json(&cfg.tasks, *qt, row, *ppl, base)
            })
            .collect();

        // HiF4-vs-NVFP4 deltas per cell, one block per mode (positive
        // acc_delta / negative ppl_delta = HiF4 better).
        let mut deltas = Vec::new();
        for m in &cfg.modes {
            let hif4 = rows.iter().find(|(qt, _, _)| *qt == m.apply(QuantKind::HiF4));
            let nvfp4 = rows.iter().find(|(qt, _, _)| *qt == m.apply(QuantKind::Nvfp4));
            if let (Some((_, hr, hp)), Some((_, nr, np))) = (hif4, nvfp4) {
                let acc_delta = Json::Obj(
                    cfg.tasks
                        .iter()
                        .zip(hr.task_acc.iter().zip(&nr.task_acc))
                        .map(|(t, (a, b))| (t.name().to_string(), Json::num(a - b)))
                        .collect(),
                );
                deltas.push(Json::obj(vec![
                    ("mode", Json::str(m.key())),
                    ("acc_delta", acc_delta),
                    ("mean_delta", Json::num(hr.mean - nr.mean)),
                    ("ppl_delta", Json::num(hp - np)),
                ]));
            }
        }

        // Per-layer sensitivity: weight-only, one class at a time.
        let mut sens = Vec::new();
        for f in &cfg.sensitivity_formats {
            let scheme = QuantScheme::direct(*f);
            for class in LayerClass::ALL {
                let qm = quantize_layer_class(&model, class, &scheme);
                let label = format!("{}:{}", f.spelling(), class.key());
                let row = evaluate(
                    &qm,
                    &label,
                    &cfg.tasks,
                    cfg.xcfg.eval_items,
                    &cfg.xcfg.eval_seeds,
                    None,
                );
                sens.push(Json::obj(vec![
                    ("format", Json::str(f.spelling())),
                    ("class", Json::str(class.key())),
                    ("mean", Json::num(row.mean)),
                    ("acc_drop_mean", Json::num(row.mean - base_mean)),
                ]));
            }
        }

        eprintln!(
            "[battery] {key}: loss {:.3} -> {:.3}, {} rows + {} sensitivity cells in {:.1?}",
            losses[0],
            losses.last().unwrap(),
            rows.len(),
            sens.len(),
            t0.elapsed()
        );
        models_json.push(Json::obj(vec![
            ("key", Json::str(key.as_str())),
            ("name", Json::str(mcfg.name.as_str())),
            ("final_train_loss", Json::num(*losses.last().unwrap() as f64)),
            ("rows", Json::Arr(rows_json)),
            ("hif4_vs_nvfp4", Json::Arr(deltas)),
            ("sensitivity", Json::Arr(sens)),
        ]));
    }

    Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("suite", Json::str(if cfg.quick { "quick" } else { "full" })),
        ("tasks", Json::arr(cfg.tasks.iter().map(|t| Json::str(t.name())))),
        ("formats", Json::arr(cfg.formats.iter().map(|f| Json::str(f.spelling())))),
        ("modes", Json::arr(cfg.modes.iter().map(|m| Json::str(m.key())))),
        ("models", Json::Arr(models_json)),
    ])
}

fn row_json(
    tasks: &[Task],
    qt: QuantType,
    row: &EvalRow,
    ppl: f64,
    base: Option<(f64, f64)>,
) -> Json {
    let acc = Json::Obj(
        tasks
            .iter()
            .zip(&row.task_acc)
            .map(|(t, a)| (t.name().to_string(), Json::num(*a)))
            .collect(),
    );
    let mut pairs = vec![
        ("quant", Json::str(qt.key())),
        ("label", Json::str(qt.label())),
        ("acc", acc),
        ("mean", Json::num(row.mean)),
        ("ppl", Json::num(ppl)),
    ];
    match base {
        Some((base_mean, base_ppl)) => {
            pairs.push(("acc_drop_mean", Json::num(row.mean - base_mean)));
            pairs.push(("ppl_ratio", Json::num(ppl / base_ppl)));
        }
        None => {
            pairs.push(("acc_drop_mean", Json::Null));
            pairs.push(("ppl_ratio", Json::Null));
        }
    }
    Json::obj(pairs)
}

/// Render a battery document as the human-readable per-model tables the
/// CLI and bench print next to the JSON artifact.
pub fn print_tables(doc: &Json) {
    use crate::util::bench::Table;
    let tasks: Vec<&str> = doc
        .get("tasks")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_str).collect())
        .unwrap_or_default();
    for model in doc.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
        let name = model.get("name").and_then(Json::as_str).unwrap_or("?");
        let mut header = vec!["quant", "label"];
        header.extend(&tasks);
        header.extend(["mean", "ppl", "drop"]);
        let mut t = Table::new(name, &header);
        for row in model.get("rows").and_then(Json::as_arr).unwrap_or(&[]) {
            let mut cells = vec![
                row.get("quant").and_then(Json::as_str).unwrap_or("?").to_string(),
                row.get("label").and_then(Json::as_str).unwrap_or("?").to_string(),
            ];
            for &task in &tasks {
                let v = row.get("acc").and_then(|a| a.get(task)).and_then(Json::as_f64);
                cells.push(v.map_or("-".into(), |v| format!("{v:.2}")));
            }
            let mean = row.get("mean").and_then(Json::as_f64).unwrap_or(f64::NAN);
            let ppl = row.get("ppl").and_then(Json::as_f64).unwrap_or(f64::NAN);
            cells.push(format!("{mean:.2}"));
            cells.push(format!("{ppl:.2}"));
            cells.push(
                row.get("acc_drop_mean")
                    .and_then(Json::as_f64)
                    .map_or("-".into(), |d| format!("{d:+.2}")),
            );
            t.row(cells);
        }
        t.print();
        let mut s = Table::new(
            &format!("{name} — per-layer sensitivity (weight-only, drop vs BF16)"),
            &["format", "class", "mean", "drop"],
        );
        for cell in model.get("sensitivity").and_then(Json::as_arr).unwrap_or(&[]) {
            s.row(vec![
                cell.get("format").and_then(Json::as_str).unwrap_or("?").to_string(),
                cell.get("class").and_then(Json::as_str).unwrap_or("?").to_string(),
                format!("{:.2}", cell.get("mean").and_then(Json::as_f64).unwrap_or(f64::NAN)),
                format!(
                    "{:+.2}",
                    cell.get("acc_drop_mean").and_then(Json::as_f64).unwrap_or(f64::NAN)
                ),
            ]);
        }
        s.print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_class_quantization_touches_only_its_class() {
        let model = Transformer::init(zoo::deepseek_tiny(), 3);
        let scheme = QuantScheme::direct(QuantKind::HiF4);
        for class in LayerClass::ALL {
            let qm = quantize_layer_class(&model, class, &scheme);
            // Embedding changes iff class == Embed.
            let embed_changed = qm.w.embed.data != model.w.embed.data;
            assert_eq!(embed_changed, class == LayerClass::Embed, "{:?}", class);
            // Per-linear: only the matching kinds change, gate/head never.
            let mut changed: Vec<(LayerKind, bool)> = Vec::new();
            let mut originals = std::collections::HashMap::new();
            model.visit_linears(&mut |lin| {
                originals.insert(lin.name.clone(), lin.w.data.clone());
            });
            qm.visit_linears(&mut |lin| {
                changed.push((lin.kind, originals[&lin.name] != lin.w.data));
            });
            for (kind, did_change) in changed {
                let expect = match class {
                    LayerClass::Attn => kind == LayerKind::AttnLinear,
                    LayerClass::Mlp => {
                        matches!(kind, LayerKind::FfnLinear | LayerKind::MoeExpert)
                    }
                    LayerClass::Embed => false,
                };
                // Quantization may be a no-op on an already-representable
                // tensor, but must never touch the wrong class.
                if !expect {
                    assert!(!did_change, "{class:?} must not touch {kind:?}");
                }
            }
        }
    }

    #[test]
    fn layer_class_quantization_changes_target_class_weights() {
        // With random (non-representable) weights, the targeted class must
        // actually change.
        let model = Transformer::init(zoo::llama2_tiny(), 5);
        let scheme = QuantScheme::direct(QuantKind::Nvfp4);
        let qm = quantize_layer_class(&model, LayerClass::Attn, &scheme);
        let mut any_changed = false;
        let mut originals = std::collections::HashMap::new();
        model.visit_linears(&mut |lin| {
            originals.insert(lin.name.clone(), lin.w.data.clone());
        });
        qm.visit_linears(&mut |lin| {
            if lin.kind == LayerKind::AttnLinear && originals[&lin.name] != lin.w.data {
                any_changed = true;
            }
        });
        assert!(any_changed, "attn weights should move under 4-bit quantization");
    }

    #[test]
    fn union_suite_covers_both_tables_without_duplicates() {
        let suite = union_suite();
        assert_eq!(suite.len(), 11);
        for t in Task::small_suite().into_iter().chain(Task::large_suite()) {
            assert!(suite.contains(&t), "{} missing", t.name());
        }
        let mut names: Vec<&str> = suite.iter().map(|t| t.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 11, "duplicate tasks in the union suite");
    }

    #[test]
    fn quick_config_covers_the_required_axes() {
        let cfg = BatteryConfig::quick();
        let types = cfg.quant_types();
        // 2 formats × 3 modes + 1 fixed row.
        assert_eq!(types.len(), 7);
        assert!(types.contains(&QuantType::HiGptq(QuantKind::Nvfp4)));
        assert!(types.contains(&QuantType::Packed(QuantKind::HiF4)), "kernel-backend row");
        // Keys unique (JSON rows must not collide).
        let mut keys: Vec<String> = types.iter().map(|t| t.key()).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n);
        let full = BatteryConfig::full();
        assert_eq!(full.quant_types().len(), 5 * 3 + 2);
        assert_eq!(full.models.len(), 6);
        assert_eq!(full.tasks.len(), 11);
    }

    #[test]
    fn tiny_battery_produces_the_documented_shape() {
        // A deliberately minimal config (1 model, 1 format, 2 tasks, tiny
        // budgets) exercises the whole pipeline: training, all four modes,
        // ppl, deltas (absent: no NVFP4), sensitivity, JSON shape.
        let cfg = BatteryConfig {
            quick: true,
            models: vec!["llama2".to_string()],
            formats: vec![QuantKind::HiF4],
            modes: vec![QuantMode::Direct],
            fixed_formats: vec![],
            tasks: vec![Task::AgreeEasy, Task::YesNo],
            xcfg: ExperimentConfig {
                train_steps: 25,
                eval_items: 8,
                eval_seeds: vec![1],
                calib_rows: 64,
                ..ExperimentConfig::default()
            },
            ppl: PplConfig { n_seqs: 2, seq_len: 16, seed: 11, batch: 2 },
            sensitivity_formats: vec![QuantKind::HiF4],
        };
        let doc = run(&cfg);
        assert_eq!(doc.get("schema_version").and_then(Json::as_f64), Some(1.0));
        assert_eq!(doc.get("suite").and_then(Json::as_str), Some("quick"));
        let models = doc.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(models.len(), 1);
        let rows = models[0].get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2, "bf16 + hif4 direct");
        assert_eq!(rows[0].get("quant").and_then(Json::as_str), Some("bf16"));
        assert_eq!(rows[1].get("quant").and_then(Json::as_str), Some("hif4"));
        for row in rows {
            let ppl = row.get("ppl").and_then(Json::as_f64).unwrap();
            assert!(ppl.is_finite() && ppl > 1.0, "ppl {ppl}");
            let acc = row.get("acc").and_then(Json::as_obj).unwrap();
            assert_eq!(acc.len(), 2);
        }
        // No NVFP4 in the matrix → no deltas; sensitivity = 3 classes.
        assert_eq!(models[0].get("hif4_vs_nvfp4").and_then(Json::as_arr).unwrap().len(), 0);
        let sens = models[0].get("sensitivity").and_then(Json::as_arr).unwrap();
        assert_eq!(sens.len(), 3);
        // Every numeric leaf is finite (the golden diff treats null as a
        // data bug, modulo the two intentional baseline nulls).
        for (path, v) in doc.flatten_numbers() {
            assert!(v.is_finite(), "{path} = {v}");
        }
        // Determinism: the whole document reruns bit-identically.
        let doc2 = run(&cfg);
        assert_eq!(doc.render(), doc2.render());
        // And parses back from its own rendering.
        let reparsed = crate::util::json::parse(&doc.render()).unwrap();
        assert_eq!(reparsed.flatten_numbers(), doc.flatten_numbers());
    }
}
