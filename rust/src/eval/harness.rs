//! Likelihood-based multiple-choice scoring — the standard LLM-benchmark
//! protocol (lm-eval-harness style): each choice is scored by the
//! length-normalized sum of log-probabilities of its tokens given the
//! context; the argmax choice is compared with gold.
//!
//! The scoring math is factored into pure functions over a logits matrix
//! ([`score_choices`], [`argmax_first`]) so the battery's unit tests can
//! pin it on hand-computed inputs, independent of any model.

use super::tasks::{Item, Task};
use crate::model::transformer::{QuantPolicy, Transformer};
use crate::tensor::{Matrix, Rng};

/// Accuracy of `model` on `n` items of `task` (percent).
pub fn task_accuracy(
    model: &Transformer,
    task: Task,
    n: usize,
    seed: u64,
    policy: Option<&QuantPolicy>,
) -> f64 {
    let mut rng = Rng::seed(seed);
    let mut correct = 0usize;
    for _ in 0..n {
        let item = task.item(&mut rng);
        if predict(model, &item, policy) == item.gold {
            correct += 1;
        }
    }
    100.0 * correct as f64 / n as f64
}

/// Argmax choice index under length-normalized log-likelihood.
pub fn predict(model: &Transformer, item: &Item, policy: Option<&QuantPolicy>) -> usize {
    argmax_first(&choice_scores(model, item, policy))
}

/// Per-choice length-normalized log-likelihoods: batch all choices as full
/// sequences (context ++ choice) through one forward, then score.
pub fn choice_scores(model: &Transformer, item: &Item, policy: Option<&QuantPolicy>) -> Vec<f64> {
    let seqs: Vec<Vec<usize>> = item
        .choices
        .iter()
        .map(|ch| {
            let mut s = item.context.clone();
            s.extend_from_slice(ch);
            s
        })
        .collect();
    let logits = model.forward(&seqs, policy, None, None);
    score_choices(&logits, item)
}

/// The pure scoring rule: given the logits of the batched sequences
/// (context ++ choice, concatenated row-wise in choice order), return each
/// choice's mean log-probability over its own tokens. Length
/// normalization keeps multi-token continuations comparable to single
/// tokens (HellaSwag-style).
pub fn score_choices(logits: &Matrix, item: &Item) -> Vec<f64> {
    let ctx = item.context.len();
    let mut scores = Vec::with_capacity(item.choices.len());
    let mut row_base = 0usize;
    for ch in &item.choices {
        let mut ll = 0f64;
        for (i, &tok) in ch.iter().enumerate() {
            // logits at position p-1 predict the token at position p.
            ll += log_softmax_at(logits, row_base + ctx + i - 1, tok);
        }
        scores.push(ll / ch.len() as f64);
        row_base += ctx + ch.len();
    }
    scores
}

/// First index of the maximum score — ties resolve to the lowest index
/// (deterministic, and documented by the battery's tie test).
pub fn argmax_first(scores: &[f64]) -> usize {
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (i, s) in scores.iter().enumerate() {
        if *s > best.0 {
            best = (*s, i);
        }
    }
    best.1
}

/// Log-probability of `token` under row `row` of the logits (numerically
/// stable log-softmax in f64). Shared with [`super::ppl`].
pub(crate) fn log_softmax_at(logits: &Matrix, row: usize, token: usize) -> f64 {
    let r = logits.row(row);
    let maxv = r.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
    let denom: f64 = r.iter().map(|x| ((x - maxv) as f64).exp()).sum();
    (r[token] - maxv) as f64 - denom.ln()
}

/// A full evaluation row: accuracy per task plus the mean (one table line).
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub label: String,
    pub task_acc: Vec<f64>,
    pub mean: f64,
}

/// Evaluate a model over a task suite, averaging `seeds.len()` runs per
/// task (the paper averages 3 seeds × 2 devices; we use 3 seeds).
pub fn evaluate(
    model: &Transformer,
    label: &str,
    suite: &[Task],
    n_items: usize,
    seeds: &[u64],
    policy: Option<&QuantPolicy>,
) -> EvalRow {
    let task_acc: Vec<f64> = suite
        .iter()
        .map(|t| {
            let sum: f64 = seeds
                .iter()
                .map(|s| task_accuracy(model, *t, n_items, s ^ (*t as u64) << 32, policy))
                .sum();
            sum / seeds.len() as f64
        })
        .collect();
    let mean = task_acc.iter().sum::<f64>() / task_acc.len() as f64;
    EvalRow { label: label.to_string(), task_acc, mean }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::ppl::{perplexity, PplConfig};
    use crate::eval::tasks;
    use crate::model::config::{Attention, Ffn, ModelConfig};
    use crate::model::train::train;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "eval-tiny".into(),
            vocab: tasks::VOCAB,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            head_dim: 16,
            attention: Attention::Mha,
            ffn: Ffn::SwiGlu,
            d_ff: 64,
            max_seq: 48,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    /// Hand-checkable setting: vocab 3, two 1-token choices, context [0].
    /// Sequences batch as rows [0,c0],[0,c1] → rows 0..2 and 2..4; only
    /// rows 0 and 2 (the last context position of each sequence) score.
    fn mini_item() -> Item {
        Item { context: vec![0], choices: vec![vec![1], vec![2]], gold: 1 }
    }

    #[test]
    fn scoring_matches_hand_computed_log_softmax() {
        // Row 0 uniform: choice 0 scores ln(1/3). Row 2 favors token 2:
        // score = 1 - ln(2 + e). The second is larger, so prediction = 1
        // (= gold for mini_item): the "correct" case.
        let logits = Matrix::from_vec(
            4,
            3,
            vec![
                0.0, 0.0, 0.0, // row 0: context of choice 0 (scored)
                9.0, 9.0, 9.0, // row 1: choice-0 token position (ignored)
                0.0, 0.0, 1.0, // row 2: context of choice 1 (scored)
                9.0, 9.0, 9.0, // row 3: ignored
            ],
        );
        let item = mini_item();
        let scores = score_choices(&logits, &item);
        let expect0 = -(3f64.ln());
        let expect1 = 1.0 - (2.0 + 1f64.exp()).ln();
        assert!((scores[0] - expect0).abs() < 1e-12, "{} vs {expect0}", scores[0]);
        assert!((scores[1] - expect1).abs() < 1e-12, "{} vs {expect1}", scores[1]);
        assert_eq!(argmax_first(&scores), 1, "correct case picks gold");
    }

    #[test]
    fn scoring_incorrect_and_tie_cases() {
        // Incorrect: row 2 now *penalizes* token 2 → choice 0 wins ≠ gold.
        let bad = Matrix::from_vec(
            4,
            3,
            vec![0.0, 0.0, 0.0, 9.0, 9.0, 9.0, 0.0, 0.0, -1.0, 9.0, 9.0, 9.0],
        );
        let item = mini_item();
        let scores = score_choices(&bad, &item);
        assert!(scores[0] > scores[1]);
        assert_ne!(argmax_first(&scores), item.gold, "incorrect case misses gold");

        // Tie: identical scored rows → identical scores → lowest index wins.
        let tie = Matrix::from_vec(
            4,
            3,
            vec![0.0, 0.5, 0.5, 9.0, 9.0, 9.0, 0.0, 0.5, 0.5, 9.0, 9.0, 9.0],
        );
        let scores = score_choices(&tie, &item);
        assert_eq!(scores[0], scores[1], "scores must tie exactly");
        assert_eq!(argmax_first(&scores), 0, "ties resolve to the first choice");
    }

    #[test]
    fn length_normalization_averages_multi_token_choices() {
        // Choice 1 has two tokens; its score must be the *mean* of the two
        // per-token log-probs, not the sum (else long choices always lose).
        let item = Item { context: vec![0], choices: vec![vec![1], vec![1, 2]], gold: 0 };
        // Rows: choice 0 = [0,1] → rows 0..2 (row 0 scored);
        //       choice 1 = [0,1,2] → rows 2..5 (rows 2 and 3 scored).
        let logits = Matrix::from_vec(
            5,
            3,
            vec![
                0.0, 0.0, 0.0, // row 0: scores token 1 → -ln 3
                9.0, 9.0, 9.0, // row 1: ignored
                0.0, 0.0, 0.0, // row 2: scores token 1 → -ln 3
                0.0, 0.0, 0.0, // row 3: scores token 2 → -ln 3
                9.0, 9.0, 9.0, // row 4: ignored
            ],
        );
        let scores = score_choices(&logits, &item);
        assert!((scores[0] - scores[1]).abs() < 1e-12, "mean of equal logprobs is unchanged");
        assert!((scores[1] + 3f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn scoring_is_deterministic_per_seed() {
        // Same seed → bit-identical accuracy (twice over, and per task);
        // different seeds sample different items.
        let model = Transformer::init(tiny(), 77);
        for task in [Task::AgreeHard, Task::YesNo, Task::Arith] {
            let a = task_accuracy(&model, task, 40, 9, None);
            let b = task_accuracy(&model, task, 40, 9, None);
            assert_eq!(a.to_bits(), b.to_bits(), "{}", task.name());
        }
        let r1 = evaluate(&model, "BF16", &Task::small_suite(), 10, &[1, 2], None);
        let r2 = evaluate(&model, "BF16", &Task::small_suite(), 10, &[1, 2], None);
        assert_eq!(r1.task_acc, r2.task_acc);
        assert_eq!(r1.mean.to_bits(), r2.mean.to_bits());
    }

    #[test]
    fn untrained_model_is_at_chance() {
        let model = Transformer::init(tiny(), 77);
        let acc = task_accuracy(&model, Task::AgreeHard, 200, 1, None);
        assert!((10.0..45.0).contains(&acc), "4-way chance ≈ 25%, got {acc}");
        let acc2 = task_accuracy(&model, Task::YesNo, 200, 1, None);
        assert!((30.0..70.0).contains(&acc2), "2-way chance ≈ 50%, got {acc2}");
    }

    #[test]
    fn training_lifts_accuracy_above_chance() {
        // Short training must push easy agreement tasks well above chance —
        // the signal the PTQ tables depend on.
        let mut model = Transformer::init(tiny(), 78);
        let losses = train(&mut model, 120, 2e-3, 79, |rng| {
            (0..8).map(|_| tasks::training_sequence(rng, 32)).collect()
        });
        assert!(losses.last().unwrap() < &losses[0]);
        let acc = task_accuracy(&model, Task::AgreeEasy, 150, 2, None);
        assert!(acc > 55.0, "trained AgreeEasy should beat 25% chance: {acc}");
        let ppl = perplexity(&model, None, &PplConfig { n_seqs: 4, seed: 3, ..PplConfig::default() });
        assert!(ppl < tasks::VOCAB as f64 / 2.0, "ppl {ppl} should beat uniform");
    }

    #[test]
    fn evaluate_produces_full_row() {
        let model = Transformer::init(tiny(), 80);
        let row = evaluate(&model, "BF16", &Task::small_suite(), 20, &[1, 2], None);
        assert_eq!(row.task_acc.len(), 8);
        assert!(row.mean > 0.0 && row.mean < 100.0);
    }
}
