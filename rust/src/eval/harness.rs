//! Likelihood-based multiple-choice scoring — the standard LLM-benchmark
//! protocol (lm-eval-harness style): each choice is scored by the
//! length-normalized sum of log-probabilities of its tokens given the
//! context; the argmax choice is compared with gold.

use super::tasks::{Item, Task};
use crate::model::transformer::{QuantPolicy, Transformer};
use crate::tensor::{Matrix, Rng};

/// Accuracy of `model` on `n` items of `task` (percent).
pub fn task_accuracy(
    model: &Transformer,
    task: Task,
    n: usize,
    seed: u64,
    policy: Option<&QuantPolicy>,
) -> f64 {
    let mut rng = Rng::seed(seed);
    let mut correct = 0usize;
    for _ in 0..n {
        let item = task.item(&mut rng);
        if predict(model, &item, policy) == item.gold {
            correct += 1;
        }
    }
    100.0 * correct as f64 / n as f64
}

/// Argmax choice index under length-normalized log-likelihood.
pub fn predict(model: &Transformer, item: &Item, policy: Option<&QuantPolicy>) -> usize {
    // Batch all choices as full sequences (context ++ choice) — one forward.
    let seqs: Vec<Vec<usize>> = item
        .choices
        .iter()
        .map(|ch| {
            let mut s = item.context.clone();
            s.extend_from_slice(ch);
            s
        })
        .collect();
    let logits = model.forward(&seqs, policy, None, None);
    let mut best = (f64::NEG_INFINITY, 0usize);
    let mut row_base = 0usize;
    for (ci, seq) in seqs.iter().enumerate() {
        let ctx = item.context.len();
        let mut ll = 0f64;
        for pos in ctx..seq.len() {
            // logits at pos-1 predict token at pos.
            ll += log_softmax_at(&logits, row_base + pos - 1, seq[pos]);
        }
        let norm = ll / (seq.len() - ctx) as f64;
        if norm > best.0 {
            best = (norm, ci);
        }
        row_base += seq.len();
    }
    best.1
}

fn log_softmax_at(logits: &Matrix, row: usize, token: usize) -> f64 {
    let r = logits.row(row);
    let maxv = r.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
    let denom: f64 = r.iter().map(|x| ((x - maxv) as f64).exp()).sum();
    (r[token] - maxv) as f64 - denom.ln()
}

/// Perplexity on sampled corpus text (secondary diagnostic metric).
pub fn perplexity(model: &Transformer, n_seqs: usize, seq_len: usize, seed: u64) -> f64 {
    let mut rng = Rng::seed(seed);
    let mut nll = 0f64;
    let mut count = 0usize;
    for _ in 0..n_seqs {
        let seq = super::tasks::training_sequence(&mut rng, seq_len);
        let logits = model.forward(&[seq.clone()], None, None, None);
        for pos in 1..seq.len() {
            nll -= log_softmax_at(&logits, pos - 1, seq[pos]);
            count += 1;
        }
    }
    (nll / count as f64).exp()
}

/// A full evaluation row: accuracy per task plus the mean (one table line).
#[derive(Debug, Clone)]
pub struct EvalRow {
    pub label: String,
    pub task_acc: Vec<f64>,
    pub mean: f64,
}

/// Evaluate a model over a task suite, averaging `seeds.len()` runs per
/// task (the paper averages 3 seeds × 2 devices; we use 3 seeds).
pub fn evaluate(
    model: &Transformer,
    label: &str,
    suite: &[Task],
    n_items: usize,
    seeds: &[u64],
    policy: Option<&QuantPolicy>,
) -> EvalRow {
    let task_acc: Vec<f64> = suite
        .iter()
        .map(|t| {
            let sum: f64 = seeds
                .iter()
                .map(|s| task_accuracy(model, *t, n_items, s ^ (*t as u64) << 32, policy))
                .sum();
            sum / seeds.len() as f64
        })
        .collect();
    let mean = task_acc.iter().sum::<f64>() / task_acc.len() as f64;
    EvalRow { label: label.to_string(), task_acc, mean }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::tasks;
    use crate::model::config::{Attention, Ffn, ModelConfig};
    use crate::model::train::train;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "eval-tiny".into(),
            vocab: tasks::VOCAB,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            head_dim: 16,
            attention: Attention::Mha,
            ffn: Ffn::SwiGlu,
            d_ff: 64,
            max_seq: 48,
            rope_base: 10000.0,
            outlier_scale: 1.0,
            outlier_frac: 0.0,
        }
    }

    #[test]
    fn untrained_model_is_at_chance() {
        let model = Transformer::init(tiny(), 77);
        let acc = task_accuracy(&model, Task::AgreeHard, 200, 1, None);
        assert!((10.0..45.0).contains(&acc), "4-way chance ≈ 25%, got {acc}");
        let acc2 = task_accuracy(&model, Task::YesNo, 200, 1, None);
        assert!((30.0..70.0).contains(&acc2), "2-way chance ≈ 50%, got {acc2}");
    }

    #[test]
    fn training_lifts_accuracy_above_chance() {
        // Short training must push easy agreement tasks well above chance —
        // the signal the PTQ tables depend on.
        let mut model = Transformer::init(tiny(), 78);
        let losses = train(&mut model, 120, 2e-3, 79, |rng| {
            (0..8).map(|_| tasks::training_sequence(rng, 32)).collect()
        });
        assert!(losses.last().unwrap() < &losses[0]);
        let acc = task_accuracy(&model, Task::AgreeEasy, 150, 2, None);
        assert!(acc > 55.0, "trained AgreeEasy should beat 25% chance: {acc}");
        let ppl = perplexity(&model, 4, 32, 3);
        assert!(ppl < tasks::VOCAB as f64 / 2.0, "ppl {ppl} should beat uniform");
    }

    #[test]
    fn evaluate_produces_full_row() {
        let model = Transformer::init(tiny(), 80);
        let row = evaluate(&model, "BF16", &Task::small_suite(), 20, &[1, 2], None);
        assert_eq!(row.task_acc.len(), 8);
        assert!(row.mean > 0.0 && row.mean < 100.0);
    }
}
