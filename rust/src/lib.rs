//! # hif4 — HiFloat4 block floating-point for LLM inference
//!
//! Production-grade reproduction of *"HiFloat4 Format for Language Model
//! Inference"* (Luo et al., 2026): the HiF4 4-bit block floating-point
//! format, every baseline format it is compared against (NVFP4, MXFP4, MX4,
//! vanilla BFP), the fixed-point dot-product compute flow, a hardware
//! area/power model, post-training quantization (GPTQ / HiGPTQ), a
//! transformer model zoo with a synthetic evaluation harness, and a serving
//! coordinator that drives AOT-compiled XLA executables via PJRT.
//!
//! Three-layer architecture (see `DESIGN.md` at the repository root):
//! * **L1** Pallas kernels (`python/compile/kernels/`) — quantization hot
//!   spot, lowered at build time.
//! * **L2** JAX model (`python/compile/model.py`) — transformer fwd +
//!   train step, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** this crate — formats, quantization pipeline, eval, serving.
//!
//! All five block formats run behind one **unified quantized-tensor
//! API** ([`dotprod::quant_tensor`]): a single [`dotprod::QuantizedMatrix`]
//! (enum-dispatched over [`formats::QuantKind`], with the per-format
//! codecs behind the [`dotprod::BlockFormat`] trait) provides
//! `quantize` / `dequantize` / `pack` / `qgemm_bt` / `wire_bytes` /
//! `assert_geometry` uniformly, and one `QuantKind` parser/label feeds
//! the CLI, env knobs, manifest keys and bench JSON.
//!
//! The hot paths are data-parallel with a determinism contract: the f32
//! GEMMs ([`tensor::gemm`]), the quantized GEMMs
//! ([`dotprod::quant_tensor`]), GPTQ ([`quant::gptq`]) and the serving
//! worker pool ([`server`]) all fan out over OS threads while producing
//! **bit-identical** results for every thread count (`HIF4_THREADS` /
//! `--threads` / [`util::threadpool::set_threads`]);
//! `tests/parallel_parity.rs` pins the contract. The quantized GEMMs
//! additionally have three bit-identical kernel backends — the
//! element-wise flow reference, the decode-once packed integer planes,
//! and the default SIMD-tiled microkernel over those planes (AVX2 where
//! the CPU has it, a portable unrolled-scalar fallback elsewhere —
//! `HIF4_KERNEL` / `--kernel`, ISA via [`dotprod::simd_isa`]) — and the
//! model/serving layers run
//! quantized linears on the packed planes directly (weights packed once,
//! activations per call), including a PJRT-free native serving engine
//! ([`runtime::native`], [`server::service::Server::start_native`])
//! that decodes autoregressively with per-sequence KV caches
//! ([`model::kv`] — f32 or any block format encoded on append,
//! `--kv-cache`) under a continuous-batching scheduler
//! ([`server::batcher::ContinuousScheduler`]): requests are admitted
//! into in-flight decode batches each step and every generated token
//! streams to its client immediately.
//!
//! Offline note: the `anyhow` and `xla` dependencies resolve to in-tree
//! crates under `rust/vendor/` — a minimal error type and a PJRT stub —
//! so the workspace builds with no registry or native XLA runtime; see
//! `README.md` for swapping in the real bindings.

pub mod audit;
pub mod dotprod;
pub mod eval;
pub mod formats;
pub mod hwcost;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;
