//! # hif4 — HiFloat4 block floating-point for LLM inference
//!
//! Production-grade reproduction of *"HiFloat4 Format for Language Model
//! Inference"* (Luo et al., 2026): the HiF4 4-bit block floating-point
//! format, every baseline format it is compared against (NVFP4, MXFP4, MX4,
//! vanilla BFP), the fixed-point dot-product compute flow, a hardware
//! area/power model, post-training quantization (GPTQ / HiGPTQ), a
//! transformer model zoo with a synthetic evaluation harness, and a serving
//! coordinator that drives AOT-compiled XLA executables via PJRT.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! * **L1** Pallas kernels (`python/compile/kernels/`) — quantization hot
//!   spot, lowered at build time.
//! * **L2** JAX model (`python/compile/model.py`) — transformer fwd +
//!   train step, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** this crate — formats, quantization pipeline, eval, serving.

pub mod dotprod;
pub mod eval;
pub mod formats;
pub mod hwcost;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;
